//! Native fused flash-attention: tiled online-softmax forward that can
//! consume PAMM-compressed Q/K/V without ever materializing the full
//! projections.
//!
//! The paper's composability claim — "PAMM is fully composable with
//! efficient attention techniques such as FlashAttention" — existed in
//! this repo only as an XLA artifact pair diffed in
//! `experiments::kernels`. This module is the native realization: a
//! flash-style forward whose per-tile `Q·Kᵀ` and `P·V` contractions
//! route through the `tensor::kernels` microkernel (scalar→sse2→avx2,
//! no FMA), so the bit-identity ladder extends from GEMM to attention,
//! plus a fused entry point that produces Q/K/V strips on the fly from
//! a [`Compressed`] representation.
//!
//! # Tiling scheme
//!
//! Per (batch, head) task, the query dimension is walked in `BR`-row
//! tiles and, for each, the KV sequence in `BC`-row tiles:
//!
//! ```text
//! for i0 in seq by BR:                  // query tile, acc/m/l reset
//!   build Q strip (BR × d, pre-scaled by 1/√d)
//!   for j0 in kv_end(i0) by BC:         // kv tile walk
//!     Kᵀ panel (d × BC): dense transposes straight from the K slab
//!       and reads V in place; fused gather-scales K/V strips first
//!     S  = Qs·Kᵀ            (microkernel GEMM, zeroed tile)
//!     mask S where j > i    (causal boundary tiles only)
//!     online-softmax update (m, l, acc scaled by exp(m_prev − m_new))
//!     acc += P·V            (microkernel GEMM, accumulating)
//!   out rows = acc / l
//! ```
//!
//! Tile sizes ride the kernel's cache blocking: with `BR = BC = 64` and
//! head_dim ≤ 128, the live strips (Q, K, V, Kᵀ, S, acc ≈ 6·64·d·4 B)
//! stay inside L2 next to the kernel's packed panels, the S tile is
//! 16 KiB, and one KV strip packs into KC×NR panels that stay
//! L1-resident — the same budget reasoning as `tensor::kernels` MC/KC.
//! Causal walks skip KV tiles entirely above the diagonal (they
//! contribute exactly nothing: `exp(−1e30 − m) == 0` in f32).
//!
//! # Online-softmax recurrence
//!
//! The FlashAttention-2 form, matching the Pallas kernel
//! (`python/compile/kernels/flash_attention.py`) statement for
//! statement: `m_new = max(m, max_j S)`, `P = exp(S − m_new)`,
//! `corr = exp(m − m_new)`, `l ← l·corr + Σ P`, `acc ← acc·corr + P·V`.
//! All softmax arithmetic is portable scalar Rust; the only SIMD-level-
//! dependent work is inside the two tile GEMMs, which are bit-identical
//! across the dispatch ladder — therefore so is the whole forward.
//!
//! # Determinism contract
//!
//! * **Thread count**: parallelism only partitions the (batch·head)
//!   task grid (the attention analogue of the partition-only-M/N rule —
//!   the softmax/contraction dims are never split); each task's tile
//!   walk is a fixed serial order, and slabs are stitched by
//!   [`Pool::map_chunks_flat`] offsets. Bit-identical at any `--threads`.
//! * **Dispatch level**: the GEMM contract (no FMA, fixed accumulation
//!   order) plus scalar softmax gives `scalar == sse2 == avx2` bitwise.
//!
//! Both are property-tested on ragged shapes in
//! `rust/tests/prop_attention.rs`.
//!
//! # PAMM-fused Q/K/V
//!
//! [`pamm_qkv_attention`] takes the projection input `x`, the three
//! weight matrices and a compression budget, and never materializes
//! `Q = x·Wq` (nor K, V). Instead it uses
//! `Ã·W = diag(α)·1_f·(C·W)`: project the k generators once
//! (`G = C·W`, via [`Compressed::project_generators`]), then every
//! Q/K/V tile row is the gather-scale `α_i · G[f(i)][cols_of_head]`,
//! built directly into the per-thread tile scratch
//! (`tensor::kernels::AttnScratch`, riding the same `Workspace` TLS as
//! the GEMM packing buffers). Peak transient memory is
//! per-thread tile scratch × workers + the compressed-domain state —
//! measured, not modeled, via [`crate::memory::MemoryTracker`] and
//! bounded by [`fused_peak_bound`].
//!
//! # Backward (DESIGN.md §6)
//!
//! The training forward ([`attend_compressed_fwd_on`],
//! [`flash_attention_fwd_on`]) additionally emits the per-row
//! log-sum-exp `L = m + ln l` — together with the caller's
//! [`Compressed`], the *entire* saved-for-backward set of the fused
//! block. The backward ([`attend_compressed_bwd_on`],
//! [`flash_attention_bwd_on`]) is the FlashAttention-2 recomputation
//! walk: per tile, rebuild `P = exp(S − L)` (Q/K/V strips gather-scaled
//! from the recomputed `G = C·W` — the dense projections never exist in
//! the backward either) and form dV/dK/dQ with five microkernel GEMMs,
//! so the scalar==sse2==avx2 bit-identity ladder and the
//! partition-only-task thread determinism both extend to gradients
//! (property-tested in `rust/tests/prop_backward.rs`). The weight
//! gradients `dW = β·Ãᵀ·dY` then come from `pamm::grad_w` (the
//! gather-scaled `Cᵀ·B̃` form), composed in `crate::autograd`.

use crate::memory::MemoryTracker;
use crate::pamm::{self, Compressed, Eps};
use crate::poolx::{self, Pool};
use crate::tensor::kernels::{self, Dispatch, Workspace};
use crate::tensor::{dot, Mat};

/// Default query-tile rows per online-softmax pass.
pub const BR: usize = 64;
/// Default KV-tile rows per inner walk step.
pub const BC: usize = 64;

/// One attention tile configuration — the defaults, a config
/// `[kernels]` overlay, or a `--tune` winner. Like the GEMM k-panel,
/// Br/Bc changes regroup the online-softmax update order and therefore
/// change result *bits* (same math within the flash oracle tolerance),
/// so the process-wide values are mutated only at startup or inside
/// `pamm kernels --tune`; tests that need non-default tiles call
/// [`flash_attention_tiled`] instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnTiles {
    pub br: usize,
    pub bc: usize,
}

impl AttnTiles {
    /// The compiled-in defaults (`BR`/`BC`).
    pub fn defaults() -> AttnTiles {
        AttnTiles { br: BR, bc: BC }
    }

    pub fn validate(self) -> Result<(), String> {
        for (name, v) in [("br", self.br), ("bc", self.bc)] {
            if v < 1 {
                return Err(format!("attention tile {name} must be ≥ 1, got {v}"));
            }
        }
        Ok(())
    }
}

static BR_RT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(BR);
static BC_RT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(BC);

/// Live query-tile rows (default [`BR`]).
pub fn br() -> usize {
    BR_RT.load(std::sync::atomic::Ordering::Relaxed)
}

/// Live KV-tile rows (default [`BC`]).
pub fn bc() -> usize {
    BC_RT.load(std::sync::atomic::Ordering::Relaxed)
}

/// The tile configuration every attention entry point uses right now.
pub fn attn_tiles() -> AttnTiles {
    AttnTiles { br: br(), bc: bc() }
}

/// Install process-wide attention tiles (startup/`--tune` only — see
/// [`AttnTiles`] for why mid-run mutation is forbidden).
pub fn set_attn_tiles(t: AttnTiles) -> Result<(), String> {
    t.validate()?;
    BR_RT.store(t.br, std::sync::atomic::Ordering::Relaxed);
    BC_RT.store(t.bc, std::sync::atomic::Ordering::Relaxed);
    Ok(())
}

/// Masked-score sentinel: finite (so `m − m_new` never forms NaN) yet
/// low enough that `exp(S − m_new)` underflows to exactly `+0.0` —
/// which is what makes skipping fully-masked KV tiles bit-identical to
/// walking them. Same value as the Pallas kernel's `_NEG_INF`.
const NEG_INF: f32 = -1e30;

/// Geometry of one attention call. Q/K/V (and the output) are flat
/// `f32` slices in row-major `(batch, heads, seq, head_dim)` layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    pub batch: usize,
    pub heads: usize,
    pub seq: usize,
    pub head_dim: usize,
    pub causal: bool,
}

impl AttnShape {
    pub fn new(batch: usize, heads: usize, seq: usize, head_dim: usize, causal: bool) -> Self {
        Self { batch, heads, seq, head_dim, causal }
    }

    /// Total token rows (`batch · seq`) — the b of the PAMM papers.
    pub fn tokens(&self) -> usize {
        self.batch * self.seq
    }

    /// Width of the projected activation (`heads · head_dim`).
    pub fn d_model(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Elements of one (batch, heads, seq, head_dim) tensor.
    pub fn qkv_len(&self) -> usize {
        self.batch * self.heads * self.seq * self.head_dim
    }

    /// Bytes of ONE materialized Q/K/V tensor (×3 for all of them) —
    /// the figure the fused path's measured peak is compared against.
    pub fn tensor_bytes(&self) -> usize {
        self.qkv_len() * 4
    }

    /// Semantic flop count of the forward (`Q·Kᵀ` + `P·V`, 2 flops per
    /// MAC); the causal count sums the per-row unmasked lengths.
    pub fn flops(&self) -> f64 {
        let (b, h, l, d) = (
            self.batch as f64,
            self.heads as f64,
            self.seq as f64,
            self.head_dim as f64,
        );
        if self.causal {
            2.0 * b * h * d * l * (l + 1.0)
        } else {
            4.0 * b * h * d * l * l
        }
    }

    fn validate(&self) {
        assert!(self.head_dim >= 1, "attention: head_dim must be ≥ 1");
        assert!(
            self.head_dim <= kernels::nc(),
            "attention: head_dim {} above the kernel nc block {}",
            self.head_dim,
            kernels::nc()
        );
    }
}

/// Where one head's Q/K/V tile rows come from.
enum HeadSrc<'a> {
    /// Materialized `(seq × d)` slabs (the plain flash path).
    Dense { q: &'a [f32], k: &'a [f32], v: &'a [f32] },
    /// PAMM-compressed: row `i` of a strip is the gather-scale
    /// `α_t · G[f(t)][col0..col0+d]` with `t = tok0 + i` — the full
    /// projection never exists.
    Pamm {
        gq: &'a Mat,
        gk: &'a Mat,
        gv: &'a Mat,
        alpha: &'a [f32],
        assign: &'a [u32],
        /// First projected column of this head.
        col0: usize,
        /// First token row of this batch item.
        tok0: usize,
    },
}

/// Copy rows `[i0, i0+rows)` of a `(seq × d)` slab into `dst`,
/// multiplying by `scale` (1.0 for K/V, 1/√d for Q).
fn strip_dense(dst: &mut [f32], slab: &[f32], i0: usize, rows: usize, d: usize, scale: f32) {
    for r in 0..rows {
        let src = &slab[(i0 + r) * d..(i0 + r + 1) * d];
        let out = &mut dst[r * d..(r + 1) * d];
        if scale == 1.0 {
            out.copy_from_slice(src);
        } else {
            for (o, &s) in out.iter_mut().zip(src) {
                *o = s * scale;
            }
        }
    }
}

/// Build rows `[i0, i0+rows)` of a compressed head strip into `dst`:
/// `α_t · scale · G[f(t)][col0..col0+d]`; dropped rows (α = 0) are zero,
/// exactly like `Compressed::reconstruct`.
#[allow(clippy::too_many_arguments)]
fn strip_pamm(
    dst: &mut [f32],
    g: &Mat,
    alpha: &[f32],
    assign: &[u32],
    tok0: usize,
    col0: usize,
    i0: usize,
    rows: usize,
    d: usize,
    scale: f32,
) {
    for r in 0..rows {
        let t = tok0 + i0 + r;
        let out = &mut dst[r * d..(r + 1) * d];
        let a = alpha[t];
        if a == 0.0 {
            out.fill(0.0);
        } else {
            let gs = a * scale;
            let grow = &g.row(assign[t] as usize)[col0..col0 + d];
            for (o, &gv) in out.iter_mut().zip(grow) {
                *o = gs * gv;
            }
        }
    }
}

/// One (batch, head) slab: the full tile walk of the module docs.
/// Serial leaf computation — all parallelism lives one level up on the
/// task grid, which is exactly why thread count cannot change any
/// per-element order here.
///
/// `lse`, when given, receives the per-row log-sum-exp
/// `L_i = m_i + ln(l_i)` — the O(seq) softmax statistic the training
/// forward saves so the backward can rebuild `P = exp(S − L)` per tile
/// without storing scores (FlashAttention-2's residual).
#[allow(clippy::too_many_arguments)]
fn attend_head(
    d: Dispatch,
    src: &HeadSrc<'_>,
    seq: usize,
    dh: usize,
    causal: bool,
    t: AttnTiles,
    ws: &mut Workspace,
    out: &mut [f32],
    mut lse: Option<&mut [f32]>,
) {
    debug_assert_eq!(out.len(), seq * dh);
    let (tbr, tbc) = (t.br, t.bc);
    let scale = 1.0 / (dh as f32).sqrt();
    let Workspace { packs, attn, .. } = ws;
    attn.ensure(tbr.min(seq.max(1)), tbc.min(seq.max(1)), dh);

    for i0 in (0..seq).step_by(tbr) {
        let br = tbr.min(seq - i0);
        match src {
            HeadSrc::Dense { q, .. } => strip_dense(&mut attn.qs, q, i0, br, dh, scale),
            HeadSrc::Pamm { gq, alpha, assign, col0, tok0, .. } => {
                strip_pamm(&mut attn.qs, gq, alpha, assign, *tok0, *col0, i0, br, dh, scale)
            }
        }
        attn.m[..br].fill(NEG_INF);
        attn.l[..br].fill(0.0);
        attn.acc[..br * dh].fill(0.0);

        // Causal: the last KV tile that can hold an unmasked column for
        // this query tile is the one containing row i0+br−1; tiles
        // beyond it are fully masked and contribute exactly nothing.
        let ntiles = if causal { (i0 + br).div_ceil(tbc) } else { seq.div_ceil(tbc) };
        for jt in 0..ntiles {
            let j0 = jt * tbc;
            let bc = tbc.min(seq - j0);
            // Kᵀ panel (d × bc): the GEMM B operand of S = Qs·Kᵀ. The
            // dense path transposes straight from the K slab (and will
            // read V in place below) — the strip copies exist for the
            // gather-scale of the compressed path only.
            match src {
                HeadSrc::Dense { k, .. } => {
                    for c in 0..dh {
                        for r in 0..bc {
                            attn.kt[c * bc + r] = k[(j0 + r) * dh + c];
                        }
                    }
                }
                HeadSrc::Pamm { gk, gv, alpha, assign, col0, tok0, .. } => {
                    strip_pamm(&mut attn.ks, gk, alpha, assign, *tok0, *col0, j0, bc, dh, 1.0);
                    strip_pamm(&mut attn.vs, gv, alpha, assign, *tok0, *col0, j0, bc, dh, 1.0);
                    for c in 0..dh {
                        for r in 0..bc {
                            attn.kt[c * bc + r] = attn.ks[r * dh + c];
                        }
                    }
                }
            }
            attn.s[..br * bc].fill(0.0);
            kernels::gemm_into(
                d,
                false,
                br,
                bc,
                dh,
                &attn.qs[..br * dh],
                dh,
                &attn.kt[..dh * bc],
                bc,
                &mut attn.s[..br * bc],
                bc,
                packs,
            );
            if causal && j0 + bc > i0 + 1 {
                for r in 0..br {
                    let first_masked = (i0 + r + 1).saturating_sub(j0);
                    if first_masked < bc {
                        attn.s[r * bc + first_masked..(r + 1) * bc].fill(NEG_INF);
                    }
                }
            }
            // Online-softmax update (scalar, fixed order — see docs).
            for r in 0..br {
                let srow = &mut attn.s[r * bc..(r + 1) * bc];
                let mut mx = NEG_INF;
                for &sv in srow.iter() {
                    mx = mx.max(sv);
                }
                let m_new = attn.m[r].max(mx);
                let corr = (attn.m[r] - m_new).exp();
                let mut psum = 0.0f32;
                for sv in srow.iter_mut() {
                    *sv = (*sv - m_new).exp();
                    psum += *sv;
                }
                attn.l[r] = attn.l[r] * corr + psum;
                attn.m[r] = m_new;
                if corr != 1.0 {
                    for av in &mut attn.acc[r * dh..(r + 1) * dh] {
                        *av *= corr;
                    }
                }
            }
            // acc += P·V through the same microkernel. Dense reads the
            // V slab in place; the compressed path uses its built strip.
            let vsrc: &[f32] = match src {
                HeadSrc::Dense { v, .. } => &v[j0 * dh..(j0 + bc) * dh],
                HeadSrc::Pamm { .. } => &attn.vs[..bc * dh],
            };
            kernels::gemm_into(
                d,
                false,
                br,
                dh,
                bc,
                &attn.s[..br * bc],
                bc,
                vsrc,
                dh,
                &mut attn.acc[..br * dh],
                dh,
                packs,
            );
        }
        for r in 0..br {
            let denom = attn.l[r].max(1e-30);
            let orow = &mut out[(i0 + r) * dh..(i0 + r + 1) * dh];
            for (o, &av) in orow.iter_mut().zip(&attn.acc[r * dh..(r + 1) * dh]) {
                *o = av / denom;
            }
        }
        if let Some(stats) = lse.as_deref_mut() {
            for r in 0..br {
                stats[i0 + r] = attn.m[r] + attn.l[r].max(1e-30).ln();
            }
        }
    }
}

/// One (batch, head) slab of the FlashAttention-2 backward: recompute
/// `P = exp(S − L)` per tile from the saved log-sum-exp, then
///
/// ```text
/// D_i  = Σ_c dO[i,c]·O[i,c]                    (per head, once)
/// for j0 in seq by BC:                         (KV tile — dK/dV rows)
///   for i0 in seq by BR:                       (query tile)
///     skip if causal and the tile is fully masked
///     S  = Q̂·Kᵀ          (GEMM; Q̂ pre-scaled by 1/√d, as forward)
///     P  = exp(S − L_i)   (masked entries set to exactly 0.0)
///     dV[j0..] += Pᵀ·dO                                (GEMM)
///     dP = dO·Vᵀ                                       (GEMM)
///     dS = P ∘ (dP − D_i)
///     dK[j0..] += dSᵀ·Q̂   (scale rides Q̂)              (GEMM)
///     dQ[i0..] += (dS·scale)·K                         (GEMM)
/// ```
///
/// Five microkernel GEMMs per tile, elementwise math in portable
/// scalar Rust — the whole backward inherits the forward's
/// scalar==sse2==avx2 bit-identity and, because the walk is a fixed
/// serial order per head (parallelism only partitions the (batch·head)
/// grid one level up), its any-thread-count bit-identity too. The
/// masked-P zeros match the forward exactly (`exp(−1e30 − m)` is `+0.0`
/// there), so skipping fully-masked tiles stays bit-identical.
///
/// `dq`/`dk`/`dv` are zeroed `seq×dh` windows; accumulation into them
/// happens in ascending (j0, i0) tile order via the accumulating GEMM.
#[allow(clippy::too_many_arguments)]
fn attend_head_bwd(
    d: Dispatch,
    src: &HeadSrc<'_>,
    o: &[f32],
    dout: &[f32],
    lse: &[f32],
    seq: usize,
    dh: usize,
    causal: bool,
    t: AttnTiles,
    ws: &mut Workspace,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    debug_assert_eq!(o.len(), seq * dh);
    debug_assert_eq!(dout.len(), seq * dh);
    debug_assert_eq!(lse.len(), seq);
    let (tbr, tbc) = (t.br, t.bc);
    let scale = 1.0 / (dh as f32).sqrt();
    let Workspace { packs, attn, .. } = ws;
    attn.ensure_bwd(tbr.min(seq.max(1)), tbc.min(seq.max(1)), dh, seq.max(1));

    // D_i = Σ_c dO·O, ascending c — one fixed-order pass per head.
    for i in 0..seq {
        attn.dvec[i] = dot(&dout[i * dh..(i + 1) * dh], &o[i * dh..(i + 1) * dh]);
    }

    for j0 in (0..seq).step_by(tbc) {
        let bc = tbc.min(seq - j0);
        // K strip + d×bc Kᵀ panel, V strip + d×bc Vᵀ panel. The dense
        // path reads its K/V slabs in place for the row-major GEMM
        // operands and transposes straight from the slab; the fused
        // path gather-scales strips first, exactly like the forward.
        match src {
            HeadSrc::Dense { k, v, .. } => {
                for c in 0..dh {
                    for r in 0..bc {
                        attn.kt[c * bc + r] = k[(j0 + r) * dh + c];
                        attn.vt[c * bc + r] = v[(j0 + r) * dh + c];
                    }
                }
            }
            HeadSrc::Pamm { gk, gv, alpha, assign, col0, tok0, .. } => {
                strip_pamm(&mut attn.ks, gk, alpha, assign, *tok0, *col0, j0, bc, dh, 1.0);
                strip_pamm(&mut attn.vs, gv, alpha, assign, *tok0, *col0, j0, bc, dh, 1.0);
                for c in 0..dh {
                    for r in 0..bc {
                        attn.kt[c * bc + r] = attn.ks[r * dh + c];
                        attn.vt[c * bc + r] = attn.vs[r * dh + c];
                    }
                }
            }
        }
        for i0 in (0..seq).step_by(tbr) {
            let br = tbr.min(seq - i0);
            if causal && j0 > i0 + br - 1 {
                continue; // every (i, j) in the tile has j > i — P ≡ 0
            }
            match src {
                HeadSrc::Dense { q, .. } => strip_dense(&mut attn.qs, q, i0, br, dh, scale),
                HeadSrc::Pamm { gq, alpha, assign, col0, tok0, .. } => {
                    strip_pamm(&mut attn.qs, gq, alpha, assign, *tok0, *col0, i0, br, dh, scale)
                }
            }
            // S = Q̂·Kᵀ, then P = exp(S − L) with masked entries exactly 0.
            attn.s[..br * bc].fill(0.0);
            kernels::gemm_into(
                d,
                false,
                br,
                bc,
                dh,
                &attn.qs[..br * dh],
                dh,
                &attn.kt[..dh * bc],
                bc,
                &mut attn.s[..br * bc],
                bc,
                packs,
            );
            for r in 0..br {
                let l = lse[i0 + r];
                let srow = &mut attn.s[r * bc..(r + 1) * bc];
                for (c, sv) in srow.iter_mut().enumerate() {
                    *sv = if causal && j0 + c > i0 + r { 0.0 } else { (*sv - l).exp() };
                }
            }
            let dout_strip = &dout[i0 * dh..(i0 + br) * dh];
            // dV[j0 rows] += Pᵀ·dO (transposed read absorbed by packing).
            kernels::gemm_into(
                d,
                true,
                bc,
                dh,
                br,
                &attn.s[..br * bc],
                bc,
                dout_strip,
                dh,
                &mut dv[j0 * dh..(j0 + bc) * dh],
                dh,
                packs,
            );
            // dP = dO·Vᵀ into the dS tile.
            attn.ds[..br * bc].fill(0.0);
            kernels::gemm_into(
                d,
                false,
                br,
                bc,
                dh,
                dout_strip,
                dh,
                &attn.vt[..dh * bc],
                bc,
                &mut attn.ds[..br * bc],
                bc,
                packs,
            );
            // dS = P ∘ (dP − D_i).
            for r in 0..br {
                let dr = attn.dvec[i0 + r];
                let prow = &attn.s[r * bc..(r + 1) * bc];
                let dsrow = &mut attn.ds[r * bc..(r + 1) * bc];
                for (dsv, &pv) in dsrow.iter_mut().zip(prow) {
                    *dsv = pv * (*dsv - dr);
                }
            }
            // dK[j0 rows] += dSᵀ·Q̂ (the 1/√d rides the pre-scaled Q̂).
            kernels::gemm_into(
                d,
                true,
                bc,
                dh,
                br,
                &attn.ds[..br * bc],
                bc,
                &attn.qs[..br * dh],
                dh,
                &mut dk[j0 * dh..(j0 + bc) * dh],
                dh,
                packs,
            );
            // dQ[i0 rows] += (dS·scale)·K — K is the UNSCALED strip.
            for dsv in &mut attn.ds[..br * bc] {
                *dsv *= scale;
            }
            let ksrc: &[f32] = match src {
                HeadSrc::Dense { k, .. } => &k[j0 * dh..(j0 + bc) * dh],
                HeadSrc::Pamm { .. } => &attn.ks[..bc * dh],
            };
            kernels::gemm_into(
                d,
                false,
                br,
                dh,
                bc,
                &attn.ds[..br * bc],
                bc,
                ksrc,
                dh,
                &mut dq[i0 * dh..(i0 + br) * dh],
                dh,
                packs,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Dense flash entry points
// ---------------------------------------------------------------------------

/// Flash attention over materialized Q/K/V on the process-wide pool.
pub fn flash_attention(q: &[f32], k: &[f32], v: &[f32], shape: &AttnShape) -> Vec<f32> {
    flash_attention_with(q, k, v, shape, poolx::global())
}

/// [`flash_attention`] on an explicit pool (the bench thread sweeps).
pub fn flash_attention_with(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: &AttnShape,
    pool: &Pool,
) -> Vec<f32> {
    flash_attention_on(kernels::active(), q, k, v, shape, pool)
}

/// [`flash_attention`] on an explicit dispatch level — what the
/// property tests use to sweep the ladder without touching the
/// process-wide `kernels::force` state.
pub fn flash_attention_on(
    d: Dispatch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: &AttnShape,
    pool: &Pool,
) -> Vec<f32> {
    flash_attention_tiled(d, q, k, v, shape, pool, attn_tiles())
}

/// [`flash_attention_on`] with explicit Br/Bc tiles — how the autotune
/// sweep and the tiled property tests try candidate tile shapes
/// without mutating the process-wide [`attn_tiles`] state.
pub fn flash_attention_tiled(
    d: Dispatch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: &AttnShape,
    pool: &Pool,
    tiles: AttnTiles,
) -> Vec<f32> {
    shape.validate();
    tiles.validate().expect("attention: invalid tiles");
    let n = shape.qkv_len();
    assert_eq!(q.len(), n, "attention: q length vs shape");
    assert_eq!(k.len(), n, "attention: k length vs shape");
    assert_eq!(v.len(), n, "attention: v length vs shape");
    let (sq, dh) = (shape.seq, shape.head_dim);
    let slab = sq * dh;
    let tasks = shape.batch * shape.heads;
    pool.for_tasks().map_chunks_flat(tasks, slab, |s, e, out| {
        kernels::with_workspace(|ws| {
            for t in s..e {
                let off = t * slab;
                let src = HeadSrc::Dense {
                    q: &q[off..off + slab],
                    k: &k[off..off + slab],
                    v: &v[off..off + slab],
                };
                attend_head(
                    d,
                    &src,
                    sq,
                    dh,
                    shape.causal,
                    tiles,
                    ws,
                    &mut out[(t - s) * slab..(t - s + 1) * slab],
                    None,
                );
            }
        })
    })
}

/// Training-mode dense flash forward: like [`flash_attention_on`] but
/// also returns the per-row log-sum-exp statistics
/// (`batch·heads·seq` f32, task-major) — the O(seq) residual the
/// backward needs. Output and stats are written in one grid pass via
/// [`Pool::map_chunks_flat2`], so the determinism contract is identical
/// to the plain forward.
pub fn flash_attention_fwd_on(
    d: Dispatch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: &AttnShape,
    pool: &Pool,
) -> (Vec<f32>, Vec<f32>) {
    shape.validate();
    let n = shape.qkv_len();
    assert_eq!(q.len(), n, "attention: q length vs shape");
    assert_eq!(k.len(), n, "attention: k length vs shape");
    assert_eq!(v.len(), n, "attention: v length vs shape");
    let (sq, dh) = (shape.seq, shape.head_dim);
    let slab = sq * dh;
    let tasks = shape.batch * shape.heads;
    let tiles = attn_tiles();
    pool.for_tasks().map_chunks_flat2(tasks, slab, sq, |s, e, out, stats| {
        kernels::with_workspace(|ws| {
            for t in s..e {
                let off = t * slab;
                let src = HeadSrc::Dense {
                    q: &q[off..off + slab],
                    k: &k[off..off + slab],
                    v: &v[off..off + slab],
                };
                attend_head(
                    d,
                    &src,
                    sq,
                    dh,
                    shape.causal,
                    tiles,
                    ws,
                    &mut out[(t - s) * slab..(t - s + 1) * slab],
                    Some(&mut stats[(t - s) * sq..(t - s + 1) * sq]),
                );
            }
        })
    })
}

/// Dense flash backward: given the forward's Q/K/V slabs, output `o`,
/// upstream gradient `dout` and the saved log-sum-exp `lse`, produce
/// `(dQ, dK, dV)` in the same slab layout. Parallel over the
/// (batch·head) grid only (each head's tile walk is the fixed serial
/// order of [`attend_head_bwd`]), so the result is bit-identical at any
/// thread count and across the dispatch ladder.
#[allow(clippy::too_many_arguments)]
pub fn flash_attention_bwd_on(
    d: Dispatch,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    lse: &[f32],
    shape: &AttnShape,
    pool: &Pool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    shape.validate();
    let n = shape.qkv_len();
    for (name, buf) in [("q", q), ("k", k), ("v", v), ("o", o), ("dout", dout)] {
        assert_eq!(buf.len(), n, "attention bwd: {name} length vs shape");
    }
    let (sq, dh) = (shape.seq, shape.head_dim);
    let tasks = shape.batch * shape.heads;
    assert_eq!(lse.len(), tasks * sq, "attention bwd: lse length vs shape");
    let slab = sq * dh;
    let tiles = attn_tiles();
    let packed = pool.for_tasks().map_chunks_flat(tasks, 3 * slab, |s, e, win| {
        kernels::with_workspace(|ws| {
            for t in s..e {
                let off = t * slab;
                let src = HeadSrc::Dense {
                    q: &q[off..off + slab],
                    k: &k[off..off + slab],
                    v: &v[off..off + slab],
                };
                let base = (t - s) * 3 * slab;
                let (dq, rest) = win[base..base + 3 * slab].split_at_mut(slab);
                let (dk, dv) = rest.split_at_mut(slab);
                attend_head_bwd(
                    d,
                    &src,
                    &o[off..off + slab],
                    &dout[off..off + slab],
                    &lse[t * sq..(t + 1) * sq],
                    sq,
                    dh,
                    shape.causal,
                    tiles,
                    ws,
                    dq,
                    dk,
                    dv,
                );
            }
        })
    });
    // Unpack the [dq|dk|dv]-per-task layout into three slab tensors —
    // a deterministic reshape (pure copies at fixed offsets).
    let mut dq = vec![0f32; n];
    let mut dk = vec![0f32; n];
    let mut dv = vec![0f32; n];
    for t in 0..tasks {
        let base = t * 3 * slab;
        dq[t * slab..(t + 1) * slab].copy_from_slice(&packed[base..base + slab]);
        dk[t * slab..(t + 1) * slab].copy_from_slice(&packed[base + slab..base + 2 * slab]);
        dv[t * slab..(t + 1) * slab].copy_from_slice(&packed[base + 2 * slab..base + 3 * slab]);
    }
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// PAMM-fused entry points
// ---------------------------------------------------------------------------

/// Fused PAMM → attention forward on the process-wide pool: compress
/// the projection input `x` under the given generator budget, then run
/// the whole attention block off the compressed representation — full
/// Q/K/V activations are never resident. Returns the [`Compressed`]
/// (the activation the training path saves for backward) alongside the
/// attention output.
pub fn pamm_qkv_attention(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
) -> (Compressed, Vec<f32>) {
    pamm_qkv_attention_with(x, wq, wk, wv, gen_idx, eps, shape, poolx::global())
}

/// [`pamm_qkv_attention`] on an explicit pool.
#[allow(clippy::too_many_arguments)]
pub fn pamm_qkv_attention_with(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
    pool: &Pool,
) -> (Compressed, Vec<f32>) {
    pamm_qkv_attention_tracked(x, wq, wk, wv, gen_idx, eps, shape, pool, None)
}

/// [`pamm_qkv_attention`] with measured-peak accounting: every
/// transient the fused path allocates (compressed state, projected
/// generators, per-worker tile scratch growth) is reported to
/// `tracker`; the returned output buffer — the caller's product — is
/// not. See [`fused_peak_bound`] for the ceiling the measurement obeys.
#[allow(clippy::too_many_arguments)]
pub fn pamm_qkv_attention_tracked(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
    pool: &Pool,
    tracker: Option<&MemoryTracker>,
) -> (Compressed, Vec<f32>) {
    assert_eq!(x.rows(), shape.tokens(), "attention: x rows vs batch·seq");
    let comp = pamm::compress_with(x, gen_idx, eps, pool);
    let out = attend_compressed_on(kernels::active(), &comp, wq, wk, wv, shape, pool, tracker);
    (comp, out)
}

/// Attend straight off an existing [`Compressed`] representation, on
/// the process-wide pool (active dispatch, no tracking).
pub fn attend_compressed(
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    shape: &AttnShape,
) -> Vec<f32> {
    attend_compressed_on(kernels::active(), comp, wq, wk, wv, shape, poolx::global(), None)
}

/// The fused core: explicit dispatch level, pool and optional tracker.
///
/// Projects the generators once per weight (`G = C·W`, k rows), then
/// walks the (batch·head) grid exactly like [`flash_attention_on`],
/// except every Q/K/V strip is gather-scaled from G per tile inside the
/// worker's `AttnScratch`. The accounting contract: `comp` storage and
/// the three G matrices are alloc'd/freed around the call; per-worker
/// scratch *growth* is charged as it happens (TLS on long-lived workers
/// — a warm pool reports zero new bytes, so measure cold peaks on a
/// fresh pool).
#[allow(clippy::too_many_arguments)]
pub fn attend_compressed_on(
    d: Dispatch,
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    shape: &AttnShape,
    pool: &Pool,
    tracker: Option<&MemoryTracker>,
) -> Vec<f32> {
    attend_compressed_core(d, comp, wq, wk, wv, shape, pool, tracker, false).0
}

/// Training-mode fused forward: [`attend_compressed_on`] that also
/// returns the per-row log-sum-exp statistics (task-major,
/// `batch·heads·seq` f32). Together with the [`Compressed`] the caller
/// already holds, those statistics are the ENTIRE saved-for-backward
/// set of the fused QKV+attention block (`crate::autograd`).
#[allow(clippy::too_many_arguments)]
pub fn attend_compressed_fwd_on(
    d: Dispatch,
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    shape: &AttnShape,
    pool: &Pool,
    tracker: Option<&MemoryTracker>,
) -> (Vec<f32>, Vec<f32>) {
    let (out, lse) = attend_compressed_core(d, comp, wq, wk, wv, shape, pool, tracker, true);
    (out, lse.expect("stats requested"))
}

/// Shared fused-forward core (see [`attend_compressed_on`] for the
/// accounting contract). With `want_stats` the grid pass writes the
/// output slab and the log-sum-exp rows together through
/// [`Pool::map_chunks_flat2`]; without, the plain one-output stitch.
#[allow(clippy::too_many_arguments)]
fn attend_compressed_core(
    d: Dispatch,
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    shape: &AttnShape,
    pool: &Pool,
    tracker: Option<&MemoryTracker>,
    want_stats: bool,
) -> (Vec<f32>, Option<Vec<f32>>) {
    shape.validate();
    assert_eq!(comp.b(), shape.tokens(), "attention: compressed rows vs batch·seq");
    let dm = shape.d_model();
    if let Some(t) = tracker {
        t.alloc(comp.stored_bytes());
    }
    let (gq, gk, gv) = project_qkv_generators(comp, wq, wk, wv, shape, tracker);
    let gbytes = 3 * comp.k() * dm * 4;

    let (sq, dh) = (shape.seq, shape.head_dim);
    let slab = sq * dh;
    let tasks = shape.batch * shape.heads;
    let tiles = attn_tiles();
    let run_tasks = |s: usize, e: usize, out: &mut [f32], mut stats: Option<&mut [f32]>| {
        kernels::with_workspace(|ws| {
            let before = ws_bytes(ws);
            for t in s..e {
                let (b, h) = (t / shape.heads, t % shape.heads);
                let src = HeadSrc::Pamm {
                    gq: &gq,
                    gk: &gk,
                    gv: &gv,
                    alpha: &comp.alpha,
                    assign: &comp.assign,
                    col0: h * dh,
                    tok0: b * sq,
                };
                attend_head(
                    d,
                    &src,
                    sq,
                    dh,
                    shape.causal,
                    tiles,
                    ws,
                    &mut out[(t - s) * slab..(t - s + 1) * slab],
                    stats.as_deref_mut().map(|st| &mut st[(t - s) * sq..(t - s + 1) * sq]),
                );
            }
            if let Some(tr) = tracker {
                tr.alloc(ws_bytes(ws).saturating_sub(before));
            }
        })
    };
    let grid = pool.for_tasks();
    let (out, lse) = if want_stats {
        let (out, lse) =
            grid.map_chunks_flat2(tasks, slab, sq, |s, e, out, st| run_tasks(s, e, out, Some(st)));
        (out, Some(lse))
    } else {
        (grid.map_chunks_flat(tasks, slab, |s, e, out| run_tasks(s, e, out, None)), None)
    };
    if let Some(t) = tracker {
        t.free(gbytes);
        t.free(comp.stored_bytes());
    }
    (out, lse)
}

/// Project the generators through all three weights (`G = C·W`, k rows
/// each), charging the G bytes and the caller-thread packing growth to
/// `tracker`. Shared by the fused forward and backward (the backward
/// *recomputes* G rather than saving it — k·d_model×3 of transient
/// compute in exchange for keeping the saved-for-backward set at
/// `Compressed` + statistics only).
fn project_qkv_generators(
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    shape: &AttnShape,
    tracker: Option<&MemoryTracker>,
) -> (Mat, Mat, Mat) {
    let n_in = comp.generators.cols();
    let dm = shape.d_model();
    for (name, w) in [("wq", wq), ("wk", wk), ("wv", wv)] {
        assert_eq!(w.rows(), n_in, "attention: {name} rows vs x width");
        assert_eq!(w.cols(), dm, "attention: {name} cols vs heads·head_dim");
    }
    // The projections run on the caller thread and grow ITS workspace
    // packing buffers — a real transient of the fused path, charged
    // like the worker scratch (TLS, so only growth is new bytes).
    let packs_before = tracker.map(|_| kernels::with_workspace(|ws| ws_bytes(ws)));
    let gq = comp.project_generators(wq);
    let gk = comp.project_generators(wk);
    let gv = comp.project_generators(wv);
    if let Some(t) = tracker {
        t.alloc(3 * comp.k() * dm * 4);
        if let Some(before) = packs_before {
            t.alloc(kernels::with_workspace(|ws| ws_bytes(ws)).saturating_sub(before));
        }
    }
    (gq, gk, gv)
}

/// Fused backward of the PAMM-compressed QKV+attention block: from the
/// saved [`Compressed`], the forward output, the upstream gradient and
/// the saved log-sum-exp, produce the three **projection-space**
/// gradients `(dQᵖ, dKᵖ, dVᵖ)` as `(tokens × d_model)` matrices (head
/// slabs merged back token-major). The weight gradients then follow as
/// `dW = pamm::grad_w(comp, dYᵖ)` and the exact input gradient as
/// `dX = Σ dYᵖ·Wᵀ` — composed one level up in `crate::autograd`.
///
/// Q/K/V strips are rebuilt per tile from the recomputed `G = C·W`
/// exactly as the forward built them — the dense projections never
/// materialize in the backward either. Accounting: G, the packed
/// per-task dQ/dK/dV buffer, per-worker scratch growth AND the three
/// merged matrices (which coexist with the still-live packed buffer —
/// the true transient maximum of the backward) are all charged to
/// `tracker`; on return the merged matrices leave as the caller's
/// product (freed here, re-charged by the caller for as long as it
/// holds them — see `autograd::qkv_attn_backward_on`).
#[allow(clippy::too_many_arguments)]
pub fn attend_compressed_bwd_on(
    d: Dispatch,
    comp: &Compressed,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    o: &[f32],
    dout: &[f32],
    lse: &[f32],
    shape: &AttnShape,
    pool: &Pool,
    tracker: Option<&MemoryTracker>,
) -> (Mat, Mat, Mat) {
    shape.validate();
    assert_eq!(comp.b(), shape.tokens(), "attention bwd: compressed rows vs batch·seq");
    let n = shape.qkv_len();
    assert_eq!(o.len(), n, "attention bwd: o length vs shape");
    assert_eq!(dout.len(), n, "attention bwd: dout length vs shape");
    let (sq, dh) = (shape.seq, shape.head_dim);
    let tasks = shape.batch * shape.heads;
    assert_eq!(lse.len(), tasks * sq, "attention bwd: lse length vs shape");
    let (gq, gk, gv) = project_qkv_generators(comp, wq, wk, wv, shape, tracker);
    let gbytes = 3 * comp.k() * shape.d_model() * 4;

    let slab = sq * dh;
    if let Some(t) = tracker {
        t.alloc(tasks * 3 * slab * 4); // the packed dQ/dK/dV grid output
    }
    let tiles = attn_tiles();
    let packed = pool.for_tasks().map_chunks_flat(tasks, 3 * slab, |s, e, win| {
        kernels::with_workspace(|ws| {
            let before = ws_bytes(ws);
            for t in s..e {
                let (b, h) = (t / shape.heads, t % shape.heads);
                let src = HeadSrc::Pamm {
                    gq: &gq,
                    gk: &gk,
                    gv: &gv,
                    alpha: &comp.alpha,
                    assign: &comp.assign,
                    col0: h * dh,
                    tok0: b * sq,
                };
                let off = t * slab;
                let base = (t - s) * 3 * slab;
                let (dq, rest) = win[base..base + 3 * slab].split_at_mut(slab);
                let (dk, dv) = rest.split_at_mut(slab);
                attend_head_bwd(
                    d,
                    &src,
                    &o[off..off + slab],
                    &dout[off..off + slab],
                    &lse[t * sq..(t + 1) * sq],
                    sq,
                    dh,
                    shape.causal,
                    tiles,
                    ws,
                    dq,
                    dk,
                    dv,
                );
            }
            if let Some(tr) = tracker {
                tr.alloc(ws_bytes(ws).saturating_sub(before));
            }
        })
    });
    // The merged matrices coexist with the packed buffer until it
    // drops below — charge them up front so the tracker sees the true
    // packed+merged+G maximum, not just its tail.
    let merged_bytes = 3 * shape.tokens() * shape.d_model() * 4;
    if let Some(t) = tracker {
        t.alloc(merged_bytes);
    }
    let dqp = merge_heads_packed(&packed, 0, 3, shape);
    let dkp = merge_heads_packed(&packed, 1, 3, shape);
    let dvp = merge_heads_packed(&packed, 2, 3, shape);
    // `comp` is the caller's saved-for-backward state (accounted in the
    // ledger's saved column), so unlike the forward it is not charged
    // as a transient here; the merged matrices leave as the caller's
    // product (re-charged there while held).
    if let Some(t) = tracker {
        t.free(tasks * 3 * slab * 4);
        t.free(gbytes);
        t.free(merged_bytes);
    }
    (dqp, dkp, dvp)
}

/// The workspace bytes the fused path charges per worker: attention
/// tile scratch + the kernel packing panels it can grow.
fn ws_bytes(ws: &Workspace) -> usize {
    ws.attn.bytes() + ws.packs.capacity_bytes()
}

// ---------------------------------------------------------------------------
// Cached-decode entry point (generation)
// ---------------------------------------------------------------------------

/// One head of the cached-decode walk: queries are **dense** projection
/// rows (`q` token-major, head columns `[col0, col0+dh)`, absolute
/// positions `[pos0, pos0+q_len)`), K/V strips are gather-scaled per
/// tile from the compressed cache's projected generators — the dense
/// K/V slabs never materialize, exactly like [`attend_head`]'s Pamm
/// source. The tile walk and the online-softmax recurrence are the
/// same statements as [`attend_head`] with `i0` replaced by the
/// absolute `pos0 + i0`, so a query row computed here is bit-identical
/// whether it arrives in a many-row prefill call or a one-row decode
/// call: the per-row softmax state is independent, the S/acc GEMMs'
/// per-element accumulation order depends only on the depth, and
/// entries masked to `NEG_INF` contribute exactly `+0.0` (the same
/// argument that lets causal walks skip fully-masked tiles).
#[allow(clippy::too_many_arguments)]
fn attend_head_cached(
    d: Dispatch,
    q: &Mat,
    pos0: usize,
    col0: usize,
    gk: &Mat,
    gv: &Mat,
    alpha: &[f32],
    assign: &[u32],
    kv_len: usize,
    dh: usize,
    ws: &mut Workspace,
    out: &mut [f32],
) {
    let q_len = q.rows();
    debug_assert_eq!(out.len(), q_len * dh);
    debug_assert_eq!(kv_len, pos0 + q_len);
    let (tbr, tbc) = (br(), bc());
    let scale = 1.0 / (dh as f32).sqrt();
    let Workspace { packs, attn, .. } = ws;
    attn.ensure(tbr.min(q_len.max(1)), tbc.min(kv_len.max(1)), dh);

    for i0 in (0..q_len).step_by(tbr) {
        let br = tbr.min(q_len - i0);
        for r in 0..br {
            let src = &q.row(i0 + r)[col0..col0 + dh];
            for (o, &s) in attn.qs[r * dh..(r + 1) * dh].iter_mut().zip(src) {
                *o = s * scale;
            }
        }
        attn.m[..br].fill(NEG_INF);
        attn.l[..br].fill(0.0);
        attn.acc[..br * dh].fill(0.0);

        // Causal: walk cache tiles up to the one holding the last query
        // row's own position (self-attention includes the query row —
        // the caller folds a token into the cache *before* attending).
        let ntiles = (pos0 + i0 + br).div_ceil(tbc);
        for jt in 0..ntiles {
            let j0 = jt * tbc;
            let bc = tbc.min(kv_len - j0);
            strip_pamm(&mut attn.ks, gk, alpha, assign, 0, col0, j0, bc, dh, 1.0);
            strip_pamm(&mut attn.vs, gv, alpha, assign, 0, col0, j0, bc, dh, 1.0);
            for c in 0..dh {
                for r in 0..bc {
                    attn.kt[c * bc + r] = attn.ks[r * dh + c];
                }
            }
            attn.s[..br * bc].fill(0.0);
            kernels::gemm_into(
                d,
                false,
                br,
                bc,
                dh,
                &attn.qs[..br * dh],
                dh,
                &attn.kt[..dh * bc],
                bc,
                &mut attn.s[..br * bc],
                bc,
                packs,
            );
            if j0 + bc > pos0 + i0 + 1 {
                for r in 0..br {
                    let first_masked = (pos0 + i0 + r + 1).saturating_sub(j0);
                    if first_masked < bc {
                        attn.s[r * bc + first_masked..(r + 1) * bc].fill(NEG_INF);
                    }
                }
            }
            for r in 0..br {
                let srow = &mut attn.s[r * bc..(r + 1) * bc];
                let mut mx = NEG_INF;
                for &sv in srow.iter() {
                    mx = mx.max(sv);
                }
                let m_new = attn.m[r].max(mx);
                let corr = (attn.m[r] - m_new).exp();
                let mut psum = 0.0f32;
                for sv in srow.iter_mut() {
                    *sv = (*sv - m_new).exp();
                    psum += *sv;
                }
                attn.l[r] = attn.l[r] * corr + psum;
                attn.m[r] = m_new;
                if corr != 1.0 {
                    for av in &mut attn.acc[r * dh..(r + 1) * dh] {
                        *av *= corr;
                    }
                }
            }
            kernels::gemm_into(
                d,
                false,
                br,
                dh,
                bc,
                &attn.s[..br * bc],
                bc,
                &attn.vs[..bc * dh],
                dh,
                &mut attn.acc[..br * dh],
                dh,
                packs,
            );
        }
        for r in 0..br {
            let denom = attn.l[r].max(1e-30);
            let orow = &mut out[(i0 + r) * dh..(i0 + r + 1) * dh];
            for (o, &av) in orow.iter_mut().zip(&attn.acc[r * dh..(r + 1) * dh]) {
                *o = av / denom;
            }
        }
    }
}

/// Causal attention over a PAMM-compressed KV cache — the generation
/// entry point (`crate::generate`, DESIGN.md §8). Queries are dense
/// `(q_len × d_model)` projection rows at absolute positions
/// `[pos0, pos0 + q_len)`; keys and values for all `kv_len = pos0 +
/// q_len` cached positions are gather-scaled per tile from the
/// projected generators `gk`/`gv` (`k × d_model` each, from
/// [`Compressed::project_generators`]) with the cache's `α`/`f` rows —
/// the dense K/V slabs never exist. Parallel over the head grid only
/// (partition-only-task: each head's tile walk is a fixed serial
/// order), so the output is bit-identical at any thread count and
/// across the dispatch ladder; and because per-row softmax state is
/// independent and masked entries contribute exactly `+0.0`, a decode
/// call with one query row is bit-identical to the same row of a
/// prefill call over the whole sequence — the parity `prop_generate`
/// asserts.
#[allow(clippy::too_many_arguments)]
pub fn attend_cached_on(
    d: Dispatch,
    q: &Mat,
    pos0: usize,
    gk: &Mat,
    gv: &Mat,
    alpha: &[f32],
    assign: &[u32],
    heads: usize,
    head_dim: usize,
    pool: &Pool,
) -> Mat {
    let q_len = q.rows();
    let dm = heads * head_dim;
    let kv_len = pos0 + q_len;
    assert!(head_dim >= 1, "attend_cached: head_dim must be ≥ 1");
    assert!(head_dim <= kernels::nc(), "attend_cached: head_dim above the kernel NC block");
    assert_eq!(q.cols(), dm, "attend_cached: q width vs heads·head_dim");
    assert_eq!(gk.cols(), dm, "attend_cached: gk width vs heads·head_dim");
    assert_eq!(gv.cols(), dm, "attend_cached: gv width vs heads·head_dim");
    assert!(alpha.len() >= kv_len, "attend_cached: cache shorter than kv_len");
    assert_eq!(alpha.len(), assign.len(), "attend_cached: α/f length mismatch");
    let slab = q_len * head_dim;
    let packed = pool.for_tasks().map_chunks_flat(heads, slab, |s, e, out| {
        kernels::with_workspace(|ws| {
            for h in s..e {
                attend_head_cached(
                    d,
                    q,
                    pos0,
                    h * head_dim,
                    gk,
                    gv,
                    &alpha[..kv_len],
                    &assign[..kv_len],
                    kv_len,
                    head_dim,
                    ws,
                    &mut out[(h - s) * slab..(h - s + 1) * slab],
                );
            }
        })
    });
    merge_heads(&packed, &AttnShape::new(1, heads, q_len, head_dim, true))
}

// ---------------------------------------------------------------------------
// Memory model
// ---------------------------------------------------------------------------

/// Per-thread tile-scratch ceiling of one attention tile walk, in
/// bytes: the `AttnScratch` buffers at full (Br, Bc, d) tiles plus the
/// packing panels the two per-tile GEMMs can reserve (`Q·Kᵀ` packs
/// Br×kc / kc×Bc-strips with kc = min(d, KC); `P·V` packs Br×Bc /
/// Bc-deep d-wide strips). Valid for head_dim ≤ NC (asserted at every
/// entry point). The model counts capacities, which is sound because
/// both the scratch (`fit`) and the packing buffers (`zero_fit`) grow
/// with `reserve_exact` — never amortized doubling. Reads the
/// *runtime* Br/Bc/KC ([`attn_tiles`], [`kernels::tiles`]) so the
/// bound tracks autotuned tile installs.
pub fn tile_scratch_bytes(head_dim: usize) -> usize {
    use kernels::{MR, NR};
    let (t_br, t_bc, t_kc) = (br(), bc(), kernels::kc());
    let d = head_dim;
    let tiles = t_br * d      // qs
        + t_bc * d            // ks
        + t_bc * d            // vs
        + d * t_bc            // kt
        + t_br * t_bc         // s
        + t_br * d            // acc
        + 2 * t_br;           // m, l
    let dp = d.div_ceil(NR) * NR; // zero-padded strip width of the P·V pack
    let kc = d.min(t_kc); //        deepest k panel of the Q·Kᵀ pack
    let pa = t_br.div_ceil(MR) * MR * kc.max(t_bc);
    let pb = t_bc.div_ceil(NR) * NR * kc.max(dp);
    4 * (tiles + pa + pb)
}

/// Per-thread tile-scratch ceiling of one **backward** tile walk: the
/// forward model plus the backward-only buffers (`vt` d×Bc, `ds`
/// Br×Bc, and the seq-long `D` vector). The backward's five per-tile
/// GEMMs permute (Br, Bc, d) through the same operand roles as the
/// forward's two, so the packed-panel ceiling inside
/// [`tile_scratch_bytes`] — `pa ≤ Br·max(kc, Bc)`, `pb ≤
/// Bc·max(kc, d̂)` padded — already dominates every backward pack too;
/// only the explicit scratch grows.
pub fn bwd_tile_scratch_bytes(head_dim: usize, seq: usize) -> usize {
    let (t_br, t_bc) = (br(), bc());
    tile_scratch_bytes(head_dim) + 4 * (head_dim * t_bc + t_br * t_bc + seq)
}

/// Ceiling for the *tracked* peak of [`pamm_qkv_attention_tracked`]:
/// per-worker tile scratch × thread count, plus the compressed-domain
/// state (stored compression + the three projected generator matrices,
/// k rows each), plus the caller-thread packing panels the `G = C·W`
/// projections reserve. The acceptance test asserts
/// `measured peak ≤ this bound < materialized Q/K/V`.
pub fn fused_peak_bound(comp: &Compressed, shape: &AttnShape, threads: usize) -> usize {
    use kernels::{MR, NR};
    let t = kernels::tiles();
    let n_in = comp.generators.cols();
    let dm = shape.d_model();
    // G = C·W packing: pa holds ≤ min(k, MC) MR-padded rows × one KC
    // panel of n_in; pb holds ≤ min(dm, NC) NR-padded columns × the
    // same panel depth (exact capacities — see `tile_scratch_bytes`).
    let kc = n_in.min(t.kc);
    let proj_pa = comp.k().min(t.mc).div_ceil(MR) * MR * kc;
    let proj_pb = dm.min(t.nc).div_ceil(NR) * NR * kc;
    tile_scratch_bytes(shape.head_dim) * threads
        + comp.stored_bytes()
        + 3 * comp.k() * dm * 4
        + 4 * (proj_pa + proj_pb)
}

// ---------------------------------------------------------------------------
// Layout + reference helpers
// ---------------------------------------------------------------------------

/// Reshape a `(tokens × d_model)` projection into the flat
/// `(batch, heads, seq, head_dim)` slab layout the attention entry
/// points take — the materialize-then-attend path of the equivalence
/// tests and the experiment baselines.
pub fn split_heads(m: &Mat, shape: &AttnShape) -> Vec<f32> {
    assert_eq!(m.rows(), shape.tokens(), "split_heads: rows vs batch·seq");
    assert_eq!(m.cols(), shape.d_model(), "split_heads: cols vs heads·head_dim");
    let (h, l, d) = (shape.heads, shape.seq, shape.head_dim);
    let mut out = vec![0f32; shape.qkv_len()];
    for b in 0..shape.batch {
        for i in 0..l {
            let row = m.row(b * l + i);
            for hh in 0..h {
                out[((b * h + hh) * l + i) * d..((b * h + hh) * l + i + 1) * d]
                    .copy_from_slice(&row[hh * d..(hh + 1) * d]);
            }
        }
    }
    out
}

/// Inverse of [`split_heads`]: fold `(batch, heads, seq, head_dim)`
/// slabs back into a token-major `(tokens × d_model)` matrix — how the
/// backward's per-head dQ/dK/dV slabs become the projection-space
/// gradients `pamm::grad_w` consumes.
pub fn merge_heads(slabs: &[f32], shape: &AttnShape) -> Mat {
    merge_heads_packed(slabs, 0, 1, shape)
}

/// [`merge_heads`] reading lane `lane` of a packed per-task layout:
/// task `t`'s window holds `lanes` consecutive `seq × head_dim` slabs
/// (the backward grid writes `[dq|dk|dv]` per task, `lanes = 3`), and
/// this folds one of them token-major without first unpacking the
/// buffer. Pure fixed-offset copies — a deterministic reshape.
pub fn merge_heads_packed(packed: &[f32], lane: usize, lanes: usize, shape: &AttnShape) -> Mat {
    let (hh, l, d) = (shape.heads, shape.seq, shape.head_dim);
    let slab = l * d;
    assert!(lane < lanes, "merge_heads_packed: lane {lane} out of {lanes}");
    assert_eq!(
        packed.len(),
        shape.batch * hh * lanes * slab,
        "merge_heads_packed: buffer vs shape"
    );
    let mut out = Mat::zeros(shape.tokens(), shape.d_model());
    for b in 0..shape.batch {
        for h in 0..hh {
            let base = (b * hh + h) * lanes * slab + lane * slab;
            for i in 0..l {
                out.row_mut(b * l + i)[h * d..(h + 1) * d]
                    .copy_from_slice(&packed[base + i * d..base + (i + 1) * d]);
            }
        }
    }
    out
}

/// Materialized-scores reference attention: one `(seq × seq)` score
/// matrix per head, plain f32 softmax. This is the *baseline* the
/// experiment table and bench time against (the memory the flash walk
/// erases); the test oracle is an independent f64 implementation in
/// `rust/tests/prop_attention.rs`.
pub fn naive_attention(q: &[f32], k: &[f32], v: &[f32], shape: &AttnShape) -> Vec<f32> {
    shape.validate();
    let n = shape.qkv_len();
    assert_eq!(q.len(), n);
    assert_eq!(k.len(), n);
    assert_eq!(v.len(), n);
    let (l, d) = (shape.seq, shape.head_dim);
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0f32; n];
    let mut scores = vec![0f32; l * l];
    for t in 0..shape.batch * shape.heads {
        let off = t * l * d;
        let (qh, kh, vh) = (&q[off..off + l * d], &k[off..off + l * d], &v[off..off + l * d]);
        for i in 0..l {
            for j in 0..l {
                scores[i * l + j] = if shape.causal && j > i {
                    NEG_INF
                } else {
                    scale * dot(&qh[i * d..(i + 1) * d], &kh[j * d..(j + 1) * d])
                };
            }
        }
        for i in 0..l {
            let srow = &mut scores[i * l..(i + 1) * l];
            let mx = srow.iter().fold(NEG_INF, |a, &b| a.max(b));
            let mut sum = 0.0f32;
            for s in srow.iter_mut() {
                *s = (*s - mx).exp();
                sum += *s;
            }
            let denom = sum.max(1e-30);
            let orow = &mut out[off + i * d..off + (i + 1) * d];
            for (j, &p) in srow.iter().enumerate() {
                let pv = p / denom;
                if pv != 0.0 {
                    for (o, &vv) in orow.iter_mut().zip(&vh[j * d..(j + 1) * d]) {
                        *o += pv * vv;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Xoshiro256;

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        let mut v = vec![0f32; len];
        rng.fill_normal_f32(&mut v, 1.0);
        v
    }

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        Mat::random_normal(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn flash_matches_naive_on_small_shapes() {
        for &(b, h, l, d, causal) in
            &[(1usize, 1usize, 5usize, 4usize, false), (2, 2, 9, 8, true), (1, 2, BR + 1, 8, true)]
        {
            let shape = AttnShape::new(b, h, l, d, causal);
            let q = rand_vec(shape.qkv_len(), 1);
            let k = rand_vec(shape.qkv_len(), 2);
            let v = rand_vec(shape.qkv_len(), 3);
            let want = naive_attention(&q, &k, &v, &shape);
            let got = flash_attention_with(&q, &k, &v, &shape, &Pool::serial());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                    "b={b} h={h} l={l} d={d} causal={causal} elem {i}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn fused_matches_materialize_then_attend() {
        let shape = AttnShape::new(2, 2, 33, 8, true);
        let dm = shape.d_model();
        let x = rand_mat(shape.tokens(), dm, 10);
        let wq = rand_mat(dm, dm, 11);
        let wk = rand_mat(dm, dm, 12);
        let wv = rand_mat(dm, dm, 13);
        let mut rng = Xoshiro256::new(14);
        let idx = pamm::sample_generators(&mut rng, shape.tokens(), 12);
        let pool = Pool::serial();
        let (comp, fused) =
            pamm_qkv_attention_with(&x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool);
        // Materialize Ã, project densely, attend — must agree with the
        // fused gather-scale path up to GEMM association rounding.
        let xr = comp.reconstruct();
        let q = split_heads(&xr.matmul(&wq), &shape);
        let k = split_heads(&xr.matmul(&wk), &shape);
        let v = split_heads(&xr.matmul(&wv), &shape);
        let want = flash_attention_with(&q, &k, &v, &shape, &pool);
        for (i, (g, w)) in fused.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "elem {i}: fused {g} vs materialized {w}"
            );
        }
    }

    #[test]
    fn split_heads_layout() {
        let shape = AttnShape::new(2, 2, 3, 2, false);
        // m[token][col] = token·100 + col; check head hh picks cols [2hh, 2hh+2).
        let m = Mat::from_fn(6, 4, |i, j| (i * 100 + j) as f32);
        let s = split_heads(&m, &shape);
        // (b=1, h=0, i=2) → token 1·3+2 = 5, cols 0..2.
        let off = ((1 * 2 + 0) * 3 + 2) * 2;
        assert_eq!(&s[off..off + 2], &[500.0, 501.0]);
        // (b=0, h=1, i=1) → token 1, cols 2..4.
        let off = ((0 * 2 + 1) * 3 + 1) * 2;
        assert_eq!(&s[off..off + 2], &[102.0, 103.0]);
    }

    #[test]
    fn merge_heads_inverts_split_heads() {
        let shape = AttnShape::new(2, 3, 5, 4, false);
        let m = Mat::from_fn(shape.tokens(), shape.d_model(), |i, j| (i * 1000 + j) as f32);
        let slabs = split_heads(&m, &shape);
        assert_eq!(merge_heads(&slabs, &shape), m);
        // Packed form: lane 1 of a 3-lane layout round-trips too.
        let slab = shape.seq * shape.head_dim;
        let tasks = shape.batch * shape.heads;
        let mut packed = vec![0f32; tasks * 3 * slab];
        for t in 0..tasks {
            packed[t * 3 * slab + slab..t * 3 * slab + 2 * slab]
                .copy_from_slice(&slabs[t * slab..(t + 1) * slab]);
        }
        assert_eq!(merge_heads_packed(&packed, 1, 3, &shape), m);
    }

    #[test]
    fn fwd_stats_match_the_output_and_a_direct_logsumexp() {
        // The stats-producing forward must return the exact same output
        // as the plain forward, and L_i must equal the masked row
        // log-sum-exp of the score matrix (within f32 rounding).
        let shape = AttnShape::new(1, 2, BR + 3, 8, true);
        let n = shape.qkv_len();
        let q = rand_vec(n, 40);
        let k = rand_vec(n, 41);
        let v = rand_vec(n, 42);
        let pool = Pool::serial();
        let d = kernels::active();
        let plain = flash_attention_on(d, &q, &k, &v, &shape, &pool);
        let (out, lse) = flash_attention_fwd_on(d, &q, &k, &v, &shape, &pool);
        assert_eq!(out, plain, "stats pass must not perturb the output");
        assert_eq!(lse.len(), shape.batch * shape.heads * shape.seq);
        let (l, dh) = (shape.seq, shape.head_dim);
        let scale = 1.0 / (dh as f32).sqrt();
        for t in 0..shape.batch * shape.heads {
            let off = t * l * dh;
            for i in 0..l {
                let mut scores = Vec::new();
                for j in 0..=i {
                    scores.push(
                        scale
                            * dot(&q[off + i * dh..off + (i + 1) * dh], &k[off + j * dh..off + (j + 1) * dh]),
                    );
                }
                let mx = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let want = mx + scores.iter().map(|s| (s - mx).exp()).sum::<f32>().ln();
                let got = lse[t * l + i];
                assert!(
                    (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "task {t} row {i}: lse {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn dense_backward_zero_grad_for_zero_dout() {
        let shape = AttnShape::new(1, 2, 20, 8, true);
        let n = shape.qkv_len();
        let q = rand_vec(n, 50);
        let k = rand_vec(n, 51);
        let v = rand_vec(n, 52);
        let pool = Pool::serial();
        let d = kernels::active();
        let (o, lse) = flash_attention_fwd_on(d, &q, &k, &v, &shape, &pool);
        let dout = vec![0f32; n];
        let (dq, dk, dv) =
            flash_attention_bwd_on(d, &q, &k, &v, &o, &dout, &lse, &shape, &pool);
        assert!(dq.iter().chain(&dk).chain(&dv).all(|&x| x == 0.0));
    }

    #[test]
    fn cached_decode_matches_prefill_bitwise() {
        // One-shot prefill over the whole sequence vs one-row decode
        // calls against the same cache must agree bit-for-bit — the
        // generation parity contract (kv walks differ only in masked
        // entries that contribute exactly +0.0).
        let (heads, dh, seq) = (2usize, 8usize, BC + 9);
        let dm = heads * dh;
        let x = rand_mat(seq, dm, 60);
        let wk = rand_mat(dm, dm, 61);
        let wv = rand_mat(dm, dm, 62);
        let mut rng = Xoshiro256::new(63);
        let idx = pamm::sample_generators(&mut rng, seq, 10);
        let pool = Pool::serial();
        let comp = pamm::compress_with(&x, &idx, Eps::Inf, &pool);
        let gk = comp.project_generators(&wk);
        let gv = comp.project_generators(&wv);
        let q = rand_mat(seq, dm, 64);
        let d = kernels::active();
        let one = attend_cached_on(d, &q, 0, &gk, &gv, &comp.alpha, &comp.assign, heads, dh, &pool);
        for t in 0..seq {
            let qt = Mat::from_fn(1, dm, |_, j| q.get(t, j));
            let row = attend_cached_on(
                d,
                &qt,
                t,
                &gk,
                &gv,
                &comp.alpha[..t + 1],
                &comp.assign[..t + 1],
                heads,
                dh,
                &pool,
            );
            let got: Vec<u32> = row.row(0).iter().map(|v| v.to_bits()).collect();
            let want: Vec<u32> = one.row(t).iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "decode row {t} diverges from prefill");
        }
    }

    #[test]
    fn cached_decode_matches_naive_on_reconstructed_kv() {
        // Semantics pin: attending the cache == naive attention over
        // the materialized Ã·W keys/values (up to GEMM rounding).
        let (heads, dh, seq) = (2usize, 4usize, 21usize);
        let dm = heads * dh;
        let x = rand_mat(seq, dm, 70);
        let wk = rand_mat(dm, dm, 71);
        let wv = rand_mat(dm, dm, 72);
        let mut rng = Xoshiro256::new(73);
        let idx = pamm::sample_generators(&mut rng, seq, 6);
        let pool = Pool::serial();
        let comp = pamm::compress_with(&x, &idx, Eps::Inf, &pool);
        let gk = comp.project_generators(&wk);
        let gv = comp.project_generators(&wv);
        let q = rand_mat(seq, dm, 74);
        let got = attend_cached_on(
            kernels::active(), &q, 0, &gk, &gv, &comp.alpha, &comp.assign, heads, dh, &pool,
        );
        let shape = AttnShape::new(1, heads, seq, dh, true);
        let xr = comp.reconstruct();
        let want = naive_attention(
            &split_heads(&q, &shape),
            &split_heads(&xr.matmul(&wk), &shape),
            &split_heads(&xr.matmul(&wv), &shape),
            &shape,
        );
        let want = merge_heads(&want, &shape);
        for i in 0..seq {
            for j in 0..dm {
                let (g, w) = (got.get(i, j), want.get(i, j));
                assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0), "({i},{j}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn cached_decode_thread_and_dispatch_parity() {
        let (heads, dh, seq) = (4usize, 8usize, 40usize);
        let dm = heads * dh;
        let x = rand_mat(seq, dm, 80);
        let wk = rand_mat(dm, dm, 81);
        let wv = rand_mat(dm, dm, 82);
        let mut rng = Xoshiro256::new(83);
        let idx = pamm::sample_generators(&mut rng, seq, 7);
        let serial = Pool::serial();
        let comp = pamm::compress_with(&x, &idx, Eps::Inf, &serial);
        let gk = comp.project_generators(&wk);
        let gv = comp.project_generators(&wv);
        let q = rand_mat(seq, dm, 84);
        let base = attend_cached_on(
            Dispatch::Scalar, &q, 0, &gk, &gv, &comp.alpha, &comp.assign, heads, dh, &serial,
        );
        for d in [Dispatch::Sse2, Dispatch::Avx2] {
            if !d.available() {
                continue;
            }
            let got =
                attend_cached_on(d, &q, 0, &gk, &gv, &comp.alpha, &comp.assign, heads, dh, &serial);
            assert_eq!(got, base, "dispatch {d:?}");
        }
        for threads in [2usize, 4] {
            let pool = Pool::new(threads).with_min_chunk(1);
            let got = attend_cached_on(
                kernels::active(), &q, 0, &gk, &gv, &comp.alpha, &comp.assign, heads, dh, &pool,
            );
            let want = attend_cached_on(
                kernels::active(), &q, 0, &gk, &gv, &comp.alpha, &comp.assign, heads, dh, &serial,
            );
            assert_eq!(got, want, "threads {threads}");
        }
    }

    #[test]
    fn flops_and_bounds_sanity() {
        let sh = AttnShape::new(1, 2, 128, 32, false);
        assert_eq!(sh.flops(), 4.0 * 2.0 * 32.0 * 128.0 * 128.0);
        let causal = AttnShape { causal: true, ..sh };
        assert!(causal.flops() < sh.flops());
        assert!(tile_scratch_bytes(64) > tile_scratch_bytes(32));
        // The scratch model is far below one materialized tensor at
        // real sequence lengths.
        assert!(tile_scratch_bytes(64) < AttnShape::new(1, 1, 2048, 64, true).tensor_bytes());
        // Backward scratch = forward + exactly vt/ds/D.
        assert_eq!(
            bwd_tile_scratch_bytes(64, 512),
            tile_scratch_bytes(64) + 4 * (64 * BC + BR * BC + 512)
        );
    }
}
