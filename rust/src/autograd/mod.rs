//! Reverse-mode autograd for the native hot path — the
//! compressed-activation training step of the paper, end to end in
//! Rust (DESIGN.md §6), generalized to a **multi-op graph tape** so a
//! whole GPT-style block stack trains natively (DESIGN.md §7).
//!
//! Two levels live here:
//!
//! 1. **Fused-block primitives** ([`qkv_attn_forward_on`] /
//!    [`qkv_attn_backward_on`]): the PAMM-compressed QKV projection
//!    fused with flash attention. The forward saves **only** the
//!    [`Compressed`] struct plus the per-row softmax log-sum-exp
//!    (O(seq) per head); the backward is the FlashAttention-2
//!    recomputation walk of `attention::attend_compressed_bwd_on`
//!    followed by `dW = β·Cᵀ·B̃` via [`pamm::grad_w`] (the `Ãᵀ·dY`
//!    form, never a dense `b×n` residual contraction) and the exact
//!    `dX = Σ dYᵖ·Wᵀ`. `α`/`f` are straight-through constants of the
//!    forward, exactly like the JAX custom-vjp in
//!    `python/compile/pamm_layer.py`.
//! 2. **The graph [`Tape`]**: a reverse-mode tape over an [`Op`] enum —
//!    embedding lookup, layernorm, the fused PAMM-QKV attention block,
//!    residual add, PAMM-compressed MLP (linear → GELU → linear), tied
//!    LM head and softmax cross-entropy. The forward builder methods
//!    execute the op, push a node holding its **minimal saved state**
//!    (see the table below) and hand back the output plus a
//!    [`ValueId`]; [`Tape::backward`] walks the nodes in reverse,
//!    accumulating activation gradients per value and parameter
//!    gradients per [`ParamId`]. `rust/src/model` stacks N transformer
//!    blocks on top of it.
//!
//! # Saved-for-backward inventory (per op)
//!
//! | op | saved between fwd and bwd |
//! |---|---|
//! | embedding | token ids (u32 per token) |
//! | layernorm | input `x` + per-row mean/rstd |
//! | fused QKV attention | [`Compressed`] + log-sum-exp + the output slab `O` |
//! | residual add | nothing |
//! | PAMM MLP | [`Compressed`] only — `z = Ã·W₁` and `h = GELU(z)` are **recomputed** in the backward from the saved compression |
//! | tied LM head | its input `x` (final LN output, once per model) |
//! | mean pool | nothing (geometry only) |
//! | linear head | its pooled input `x` (`batch×d_model`, once per model) |
//! | softmax cross-entropy | `dlogits` (the backward seed) |
//!
//! The projection-layer activations — the paper's headline quantity —
//! never persist densely: both the QKV projections and the MLP hidden
//! activation are represented by their `Compressed` structs between
//! forward and backward. What *does* persist densely (layernorm inputs
//! = the residual stream, the attention output `O`, the head input) is
//! exactly what a dense autodiff keeps too, so the ledger's
//! compression-factor row compares like against like
//! (`model::dense_block_saved_bytes`).
//!
//! # Determinism
//!
//! Every contraction routes through `tensor::kernels` (no-FMA
//! scalar==sse2==avx2 bit-identity) and partitions work only over the
//! (batch·head) grid / output rows / output columns on `poolx`; all
//! elementwise math (layernorm, GELU, softmax cross-entropy, the
//! embedding scatter) is fixed-order scalar f32 on the caller thread —
//! so loss, gradients and updated weights are **bit-identical at any
//! thread count and at every dispatch level**
//! (`rust/tests/prop_backward.rs`, `rust/tests/prop_model.rs`).
//!
//! # Memory ledger
//!
//! A tracked step fills a [`MemoryLedger`]: forward transients, the
//! exact saved-for-backward total (each node records its
//! `saved_bytes()`), and backward transients — the backward peak
//! asserted against [`backward_peak_bound`] for one fused block and
//! against `model::backward_peak_bound` (layers × per-block bound +
//! block-stack residual slack) for a whole model. The charged set is
//! the fused block's transients (via the tracked
//! [`qkv_attn_backward_on`] path) and the MLP op's recomputed
//! G₁/z/h/dz + transposed weights; documented undermeasures — the
//! per-worker B̃ growth inside `pamm::grad_w`, pool packing growth
//! during dense MLP/head GEMMs, the split-heads copy of the upstream
//! gradient, and the activation-gradient chain itself (a product, by
//! the same convention as returned gradients) — are covered by the
//! bounds' per-worker and residual-slack terms.

use crate::attention::{self, AttnShape};
use crate::memory::MemoryLedger;
use crate::pamm::{self, Compressed, Eps};
use crate::poolx::{self, Pool};
use crate::tensor::kernels::{self, Dispatch, MR, NR};
use crate::tensor::Mat;

/// Identifier of one activation value flowing through a [`Tape`].
pub type ValueId = usize;

/// Identifier of one parameter matrix in the caller's parameter list
/// (`rust/src/model` keeps `Vec<Mat>`; layernorm gains/biases are
/// `1×d_model` matrices so every parameter is a [`Mat`]).
pub type ParamId = usize;

/// Layernorm variance epsilon (matches the python model's 1e-5).
pub const LN_EPS: f32 = 1e-5;

/// Saved-for-backward state of one fused PAMM-QKV + flash-attention
/// block: the compressed projection input and the O(seq) softmax
/// statistics — nothing else. This struct *is* the paper's memory
/// story: `stored_bytes + 4·(batch·heads·seq)` versus the dense
/// `X + Q + K + V` set of an uncompressed autodiff.
#[derive(Debug, Clone)]
pub struct QkvAttnSaved {
    pub comp: Compressed,
    /// Per-row log-sum-exp of the softmax, task-major
    /// (`batch·heads·seq` f32) — FlashAttention-2's backward residual.
    pub lse: Vec<f32>,
    pub shape: AttnShape,
}

impl QkvAttnSaved {
    /// Exact bytes this node keeps live between forward and backward.
    pub fn saved_bytes(&self) -> usize {
        self.comp.stored_bytes() + self.lse.len() * 4
    }
}

/// Gradients of one fused block. `dx` is present only when requested
/// (`need_dx`): the last layer of a net feeds no one below it.
#[derive(Debug)]
pub struct QkvGrads {
    pub dwq: Mat,
    pub dwk: Mat,
    pub dwv: Mat,
    pub dx: Option<Mat>,
}

// ---------------------------------------------------------------------------
// The multi-op graph tape
// ---------------------------------------------------------------------------

/// One recorded op with its minimal saved state (see the module-level
/// inventory table). Fields are public so `rust/src/model` can walk
/// the tape for the per-layer ledger without re-deriving sizes.
#[derive(Debug)]
pub enum Op {
    /// `out[i] = Emb[ids[i]]` — saves only the token ids.
    Embedding { ids: Vec<u32>, emb: ParamId, out: ValueId },
    /// `y = g ∘ (x−μ)·rstd + b` — saves the input plus per-row μ/rstd.
    LayerNorm {
        x: Mat,
        mean: Vec<f32>,
        rstd: Vec<f32>,
        gain: ParamId,
        bias: ParamId,
        input: ValueId,
        out: ValueId,
    },
    /// The fused PAMM-QKV + flash-attention block — saves the
    /// [`QkvAttnSaved`] node (Compressed + lse) and the output slab
    /// `O` (FlashAttention-2's backward reads it for `D = Σ dO∘O`).
    QkvAttn {
        saved: QkvAttnSaved,
        out_slab: Vec<f32>,
        wq: ParamId,
        wk: ParamId,
        wv: ParamId,
        input: ValueId,
        out: ValueId,
    },
    /// `out = a + b` — saves nothing; backward fans the gradient out.
    Residual { a: ValueId, b: ValueId, out: ValueId },
    /// PAMM-compressed MLP `y = GELU(Ã·W₁)·W₂` — saves only the
    /// [`Compressed`]; `z`/`h` are recomputed in the backward.
    MlpPamm { comp: Compressed, w1: ParamId, w2: ParamId, input: ValueId, out: ValueId },
    /// `logits = x·Embᵀ` (weight tying) — saves its input `x`.
    TiedHead { x: Mat, emb: ParamId, input: ValueId, out: ValueId },
    /// `out[b] = (1/seq)·Σ_t x[b·seq+t]` — sequence mean-pooling for
    /// the classification head; saves only the geometry.
    MeanPool { batch: usize, seq: usize, input: ValueId, out: ValueId },
    /// Dense classification head `logits = x·W` over the pooled rows —
    /// saves its (small, `batch×d_model`) input.
    LinearHead { x: Mat, w: ParamId, input: ValueId, out: ValueId },
    /// Mean softmax cross-entropy — computes and saves `dlogits`, the
    /// backward seed, in the forward pass (one pass over the logits).
    SoftmaxXent { dlogits: Mat, input: ValueId },
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Embedding { .. } => "embedding",
            Op::LayerNorm { .. } => "layernorm",
            Op::QkvAttn { .. } => "qkv_attn",
            Op::Residual { .. } => "residual",
            Op::MlpPamm { .. } => "mlp_pamm",
            Op::TiedHead { .. } => "tied_head",
            Op::MeanPool { .. } => "mean_pool",
            Op::LinearHead { .. } => "linear_head",
            Op::SoftmaxXent { .. } => "softmax_xent",
        }
    }

    /// Exact bytes this node keeps live between forward and backward.
    pub fn saved_bytes(&self) -> usize {
        match self {
            Op::Embedding { ids, .. } => ids.len() * 4,
            Op::LayerNorm { x, mean, rstd, .. } => {
                x.rows() * x.cols() * 4 + (mean.len() + rstd.len()) * 4
            }
            Op::QkvAttn { saved, out_slab, .. } => saved.saved_bytes() + out_slab.len() * 4,
            Op::Residual { .. } => 0,
            Op::MlpPamm { comp, .. } => comp.stored_bytes(),
            Op::TiedHead { x, .. } => x.rows() * x.cols() * 4,
            Op::MeanPool { .. } => 0,
            Op::LinearHead { x, .. } => x.rows() * x.cols() * 4,
            Op::SoftmaxXent { dlogits, .. } => dlogits.rows() * dlogits.cols() * 4,
        }
    }
}

/// Result of [`Tape::backward`]: parameter gradients (dense, one per
/// parameter — zeros where a parameter was never touched) plus the
/// per-value activation gradients for leaves the caller seeded or
/// wants to inspect (tests).
#[derive(Debug)]
pub struct BackwardResult {
    pub params: Vec<Mat>,
    pub values: Vec<Option<Mat>>,
}

/// Reverse-mode tape over [`Op`] nodes. Forward builder methods
/// execute the op, push the node and return `(output, ValueId)`; the
/// backward consumes the tape in reverse push order, accumulating
/// value gradients (fixed order — each value's consumers sit at fixed
/// node positions, so the f32 addition order never depends on thread
/// count) and parameter gradients. [`Tape::saved_bytes`] is the
/// whole-net saved-for-backward figure the ledger records.
#[derive(Debug, Default)]
pub struct Tape {
    nodes: Vec<Op>,
    n_values: usize,
    seeds: Vec<(ValueId, Mat)>,
}

impl Tape {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a fresh value id for a graph leaf (an input that no
    /// tape op produced). Ops allocate their own output ids.
    pub fn leaf(&mut self) -> ValueId {
        let id = self.n_values;
        self.n_values += 1;
        id
    }

    fn push(&mut self, op: Op, ledger: Option<&MemoryLedger>) {
        if let Some(l) = ledger {
            l.record_saved(op.saved_bytes());
        }
        self.nodes.push(op);
    }

    /// Seed the backward with an explicit upstream gradient for a
    /// value (op-level tests; a model's [`Tape::softmax_xent`] node
    /// seeds itself).
    pub fn seed(&mut self, vid: ValueId, grad: Mat) {
        self.seeds.push((vid, grad));
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn nodes(&self) -> &[Op] {
        &self.nodes
    }

    /// Total saved-for-backward bytes currently held by the tape.
    pub fn saved_bytes(&self) -> usize {
        self.nodes.iter().map(|n| n.saved_bytes()).sum()
    }

    /// Per-node `(op name, saved bytes)` in push order — the raw feed
    /// of the per-layer ledger table (`model::saved_inventory`).
    pub fn saved_inventory(&self) -> Vec<(&'static str, usize)> {
        self.nodes.iter().map(|n| (n.name(), n.saved_bytes())).collect()
    }

    // -- forward builders ---------------------------------------------------

    /// Embedding lookup `out[i] = emb[ids[i]]`.
    pub fn embedding(
        &mut self,
        emb: &Mat,
        emb_id: ParamId,
        ids: &[i32],
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        let dm = emb.cols();
        let mut out = Mat::zeros(ids.len(), dm);
        let mut saved = Vec::with_capacity(ids.len());
        for (i, &id) in ids.iter().enumerate() {
            assert!(id >= 0 && (id as usize) < emb.rows(), "embedding: token id {id} out of vocab");
            out.row_mut(i).copy_from_slice(emb.row(id as usize));
            saved.push(id as u32);
        }
        let vid = self.leaf();
        self.push(Op::Embedding { ids: saved, emb: emb_id, out: vid }, ledger);
        (out, vid)
    }

    /// Layernorm with learnable gain/bias (`1×n` matrices).
    #[allow(clippy::too_many_arguments)]
    pub fn layer_norm(
        &mut self,
        x: &Mat,
        xid: ValueId,
        gain: &Mat,
        gain_id: ParamId,
        bias: &Mat,
        bias_id: ParamId,
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        let (rows, n) = (x.rows(), x.cols());
        assert_eq!((gain.rows(), gain.cols()), (1, n), "layernorm: gain shape");
        assert_eq!((bias.rows(), bias.cols()), (1, n), "layernorm: bias shape");
        let inv_n = 1.0 / n as f32;
        let mut y = Mat::zeros(rows, n);
        let mut mean = vec![0f32; rows];
        let mut rstd = vec![0f32; rows];
        let (g, b) = (gain.data(), bias.data());
        for i in 0..rows {
            let xr = x.row(i);
            let mut mu = 0f32;
            for &v in xr {
                mu += v;
            }
            mu *= inv_n;
            let mut var = 0f32;
            for &v in xr {
                let d = v - mu;
                var += d * d;
            }
            var *= inv_n;
            let r = 1.0 / (var + LN_EPS).sqrt();
            mean[i] = mu;
            rstd[i] = r;
            let yr = y.row_mut(i);
            for j in 0..n {
                yr[j] = (xr[j] - mu) * r * g[j] + b[j];
            }
        }
        let vid = self.leaf();
        self.push(
            Op::LayerNorm {
                x: x.clone(),
                mean,
                rstd,
                gain: gain_id,
                bias: bias_id,
                input: xid,
                out: vid,
            },
            ledger,
        );
        (y, vid)
    }

    /// The fused PAMM-QKV causal attention block: compress `x`, attend
    /// off the compressed representation with statistics, merge heads.
    /// Saves the [`QkvAttnSaved`] node plus the output slab.
    #[allow(clippy::too_many_arguments)]
    pub fn qkv_attn(
        &mut self,
        d: Dispatch,
        x: &Mat,
        xid: ValueId,
        wq: &Mat,
        wq_id: ParamId,
        wk: &Mat,
        wk_id: ParamId,
        wv: &Mat,
        wv_id: ParamId,
        gen_idx: &[usize],
        eps: Eps,
        shape: &AttnShape,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        // The fused forward inline (not via `qkv_attn_forward_on`, whose
        // own record_saved would double-count the Compressed+lse bytes
        // next to this node's full inventory): forward transients go to
        // `ledger.forward`, the saved bytes are recorded once by `push`.
        assert_eq!(x.rows(), shape.tokens(), "autograd: x rows vs batch·seq");
        let comp = pamm::compress_with(x, gen_idx, eps, pool);
        let (out_slab, lse) = attention::attend_compressed_fwd_on(
            d,
            &comp,
            wq,
            wk,
            wv,
            shape,
            pool,
            ledger.map(|l| &l.forward),
        );
        let saved = QkvAttnSaved { comp, lse, shape: *shape };
        let merged = attention::merge_heads(&out_slab, shape);
        let vid = self.leaf();
        self.push(
            Op::QkvAttn {
                saved,
                out_slab,
                wq: wq_id,
                wk: wk_id,
                wv: wv_id,
                input: xid,
                out: vid,
            },
            ledger,
        );
        (merged, vid)
    }

    /// Residual add `out = a + b`.
    pub fn residual(
        &mut self,
        a: &Mat,
        aid: ValueId,
        b: &Mat,
        bid: ValueId,
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        let mut out = a.clone();
        out.add_assign(b);
        let vid = self.leaf();
        self.push(Op::Residual { a: aid, b: bid, out: vid }, ledger);
        (out, vid)
    }

    /// PAMM-compressed MLP: `y = GELU(Ã·W₁)·W₂` with
    /// `Ã = diag(α)·1_f·C`. The hidden activation is produced by
    /// gather-scaling the projected generators `G₁ = C·W₁` — the dense
    /// `b×d_ff` pre-activation exists only as a forward transient and
    /// is **recomputed** in the backward; the node saves the
    /// [`Compressed`] alone.
    #[allow(clippy::too_many_arguments)]
    pub fn mlp_pamm(
        &mut self,
        x: &Mat,
        xid: ValueId,
        w1: &Mat,
        w1_id: ParamId,
        w2: &Mat,
        w2_id: ParamId,
        gen_idx: &[usize],
        eps: Eps,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        assert_eq!(w1.rows(), x.cols(), "mlp: w1 rows vs x width");
        assert_eq!(w2.rows(), w1.cols(), "mlp: w2 rows vs d_ff");
        let dff = w1.cols();
        let comp = pamm::compress_with(x, gen_idx, eps, pool);
        let fwd_bytes = (comp.k() * dff + comp.b() * dff) * 4;
        if let Some(l) = ledger {
            l.forward.alloc(fwd_bytes);
        }
        let g1 = comp.project_generators(w1);
        let mut h = project_rows(&comp, &g1); // z, gelu'd in place
        for v in h.data_mut() {
            *v = gelu(*v);
        }
        let y = h.matmul_with(w2, pool);
        if let Some(l) = ledger {
            l.forward.free(fwd_bytes);
        }
        let vid = self.leaf();
        self.push(Op::MlpPamm { comp, w1: w1_id, w2: w2_id, input: xid, out: vid }, ledger);
        (y, vid)
    }

    /// Tied LM head: `logits = x·Embᵀ`. Saves its input.
    pub fn tied_head(
        &mut self,
        x: &Mat,
        xid: ValueId,
        emb: &Mat,
        emb_id: ParamId,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        assert_eq!(x.cols(), emb.cols(), "tied head: x width vs d_model");
        let et_bytes = emb.rows() * emb.cols() * 4;
        if let Some(l) = ledger {
            l.forward.alloc(et_bytes); // the materialized Embᵀ transient
        }
        let logits = x.matmul_with(&emb.transpose(), pool);
        if let Some(l) = ledger {
            l.forward.free(et_bytes);
        }
        let vid = self.leaf();
        self.push(Op::TiedHead { x: x.clone(), emb: emb_id, input: xid, out: vid }, ledger);
        (logits, vid)
    }

    /// Sequence mean-pool: collapse `batch·seq` token rows into one
    /// pooled row per sequence, `out[b] = (1/seq)·Σ_t x[b·seq+t]`.
    /// Fixed-order scalar f32 on the caller thread (ascending t) —
    /// thread- and dispatch-invariant; the node saves nothing but the
    /// geometry (the backward is a broadcast of `dout/seq`).
    pub fn mean_pool(
        &mut self,
        x: &Mat,
        xid: ValueId,
        batch: usize,
        seq: usize,
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        assert_eq!(x.rows(), batch * seq, "mean_pool: rows vs batch*seq");
        let n = x.cols();
        let inv = 1.0 / seq.max(1) as f32;
        let mut out = Mat::zeros(batch, n);
        for b in 0..batch {
            let or = out.row_mut(b);
            for t in 0..seq {
                let xr = x.row(b * seq + t);
                for j in 0..n {
                    or[j] += xr[j];
                }
            }
            for v in or.iter_mut() {
                *v *= inv;
            }
        }
        let vid = self.leaf();
        self.push(Op::MeanPool { batch, seq, input: xid, out: vid }, ledger);
        (out, vid)
    }

    /// Dense classification head: `logits = x·W` with `x` the pooled
    /// `batch×d_model` matrix and `W` a `d_model×n_classes` parameter.
    /// Saves its input — `batch` rows, not `batch·seq`, so the head's
    /// saved state is negligible next to the residual stream.
    pub fn linear_head(
        &mut self,
        x: &Mat,
        xid: ValueId,
        w: &Mat,
        w_id: ParamId,
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> (Mat, ValueId) {
        assert_eq!(x.cols(), w.rows(), "linear head: x width vs W rows");
        let logits = x.matmul_with(w, pool);
        let vid = self.leaf();
        self.push(Op::LinearHead { x: x.clone(), w: w_id, input: xid, out: vid }, ledger);
        (logits, vid)
    }

    /// Mean softmax cross-entropy over next-token targets. Loss and
    /// `dlogits = (softmax − onehot)/rows` are computed in one pass;
    /// the node stores `dlogits` as the backward seed. Fixed-order
    /// scalar f32/f64 arithmetic — thread- and dispatch-invariant.
    pub fn softmax_xent(
        &mut self,
        logits: &Mat,
        lid: ValueId,
        targets: &[i32],
        ledger: Option<&MemoryLedger>,
    ) -> f32 {
        let (rows, vocab) = (logits.rows(), logits.cols());
        assert_eq!(targets.len(), rows, "xent: targets vs logit rows");
        let inv = 1.0 / rows.max(1) as f32;
        let mut dl = Mat::zeros(rows, vocab);
        let mut loss = 0f64;
        for i in 0..rows {
            let lr = logits.row(i);
            let t = targets[i];
            assert!(t >= 0 && (t as usize) < vocab, "xent: target {t} out of vocab");
            let t = t as usize;
            let mut mx = f32::NEG_INFINITY;
            for &v in lr {
                mx = mx.max(v);
            }
            let mut sum = 0f32;
            for &v in lr {
                sum += (v - mx).exp();
            }
            let lse = mx + sum.ln();
            loss += (lse - lr[t]) as f64;
            let dr = dl.row_mut(i);
            for (j, &v) in lr.iter().enumerate() {
                let p = (v - lse).exp();
                dr[j] = (p - if j == t { 1.0 } else { 0.0 }) * inv;
            }
        }
        self.push(Op::SoftmaxXent { dlogits: dl, input: lid }, ledger);
        (loss / rows.max(1) as f64) as f32
    }

    // -- backward -----------------------------------------------------------

    /// Walk the tape in reverse, producing parameter gradients (one
    /// per entry of `params`, zeros where untouched) and leaf value
    /// gradients. With a ledger, each op's genuine transients are
    /// charged to `ledger.backward` (the attention op through the
    /// tracked `qkv_attn_backward_on` path; the MLP op's recomputed
    /// z/h/G₁ and transposed weights here); returned gradients are the
    /// caller's product and are not charged.
    pub fn backward(
        mut self,
        d: Dispatch,
        params: &[Mat],
        pool: &Pool,
        ledger: Option<&MemoryLedger>,
    ) -> BackwardResult {
        let tracker = ledger.map(|l| &l.backward);
        let mut vgrads: Vec<Option<Mat>> = (0..self.n_values).map(|_| None).collect();
        let mut pgrads: Vec<Option<Mat>> = (0..params.len()).map(|_| None).collect();
        for (vid, g) in self.seeds.drain(..) {
            acc_value(&mut vgrads, vid, g);
        }
        for node in self.nodes.drain(..).rev() {
            match node {
                Op::SoftmaxXent { dlogits, input } => {
                    acc_value(&mut vgrads, input, dlogits);
                }
                Op::TiedHead { x, emb, input, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    // dEmb += dlogitsᵀ·x (tied: the embedding op below
                    // adds its scatter into the same gradient matrix).
                    let demb = g.matmul_tn_with(&x, pool);
                    acc_param(&mut pgrads, emb, demb);
                    let dx = g.matmul_with(&params[emb], pool);
                    acc_value(&mut vgrads, input, dx);
                }
                Op::LinearHead { x, w, input, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    // dW = xᵀ·g, dx = g·Wᵀ — both tiny (`batch` rows).
                    let dw = x.matmul_tn_with(&g, pool);
                    acc_param(&mut pgrads, w, dw);
                    let dx = g.matmul_with(&params[w].transpose(), pool);
                    acc_value(&mut vgrads, input, dx);
                }
                Op::MeanPool { batch, seq, input, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    let n = g.cols();
                    let inv = 1.0 / seq.max(1) as f32;
                    let mut dx = Mat::zeros(batch * seq, n);
                    for b in 0..batch {
                        let gr = g.row(b);
                        for t in 0..seq {
                            let dr = dx.row_mut(b * seq + t);
                            for j in 0..n {
                                dr[j] = gr[j] * inv;
                            }
                        }
                    }
                    acc_value(&mut vgrads, input, dx);
                }
                Op::LayerNorm { x, mean, rstd, gain, bias, input, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    let (rows, n) = (x.rows(), x.cols());
                    let inv_n = 1.0 / n as f32;
                    let gm = params[gain].data();
                    let mut dgain = Mat::zeros(1, n);
                    let mut dbias = Mat::zeros(1, n);
                    let mut dx = Mat::zeros(rows, n);
                    for i in 0..rows {
                        let xr = x.row(i);
                        let gr = g.row(i);
                        let (mu, r) = (mean[i], rstd[i]);
                        let mut s1 = 0f32;
                        let mut s2 = 0f32;
                        for j in 0..n {
                            let xh = (xr[j] - mu) * r;
                            let dyg = gr[j] * gm[j];
                            s1 += dyg;
                            s2 += dyg * xh;
                            dgain.data_mut()[j] += gr[j] * xh;
                            dbias.data_mut()[j] += gr[j];
                        }
                        let dxr = dx.row_mut(i);
                        for j in 0..n {
                            let xh = (xr[j] - mu) * r;
                            let dyg = gr[j] * gm[j];
                            dxr[j] = r * (dyg - s1 * inv_n - xh * s2 * inv_n);
                        }
                    }
                    acc_param(&mut pgrads, gain, dgain);
                    acc_param(&mut pgrads, bias, dbias);
                    acc_value(&mut vgrads, input, dx);
                }
                Op::QkvAttn { saved, out_slab, wq, wk, wv, input, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    let dout_slab = attention::split_heads(&g, &saved.shape);
                    let grads = qkv_attn_backward_on(
                        d,
                        &saved,
                        &params[wq],
                        &params[wk],
                        &params[wv],
                        &out_slab,
                        &dout_slab,
                        true,
                        pool,
                        ledger,
                    );
                    acc_param(&mut pgrads, wq, grads.dwq);
                    acc_param(&mut pgrads, wk, grads.dwk);
                    acc_param(&mut pgrads, wv, grads.dwv);
                    acc_value(&mut vgrads, input, grads.dx.expect("need_dx"));
                }
                Op::Residual { a, b, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    acc_value(&mut vgrads, a, g.clone());
                    acc_value(&mut vgrads, b, g);
                }
                Op::MlpPamm { comp, w1, w2, input, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    let (w1m, w2m) = (&params[w1], &params[w2]);
                    let dff = w1m.cols();
                    let tokens = comp.b();
                    // Recomputed G₁/z/h + dz + the two transposed
                    // weights — the genuine transients of this op.
                    // (W₁ᵀ holds w1.rows()·d_ff floats, W₂ᵀ holds
                    // d_ff·w2.cols() — distinct when the output width
                    // differs from the input width.)
                    let charge = (comp.k() * dff
                        + 3 * tokens * dff
                        + (w1m.rows() + w2m.cols()) * dff)
                        * 4;
                    if let Some(t) = tracker {
                        t.alloc(charge);
                    }
                    let g1 = comp.project_generators(w1m);
                    let z = project_rows(&comp, &g1);
                    let mut h = z.clone();
                    for v in h.data_mut() {
                        *v = gelu(*v);
                    }
                    // dW₂ = hᵀ·dY (exact — h is a transient, not saved).
                    let dw2 = h.matmul_tn_with(&g, pool);
                    let mut dz = g.matmul_with(&w2m.transpose(), pool);
                    for (dv, &zv) in dz.data_mut().iter_mut().zip(z.data()) {
                        *dv *= gelu_grad(zv);
                    }
                    // dW₁ = β·Cᵀ·B̃ off the saved compression — the
                    // gather-scaled ApproxMM, never a b×d_ff contraction.
                    let dw1 = pamm::grad_w_with(&comp, &dz, pool);
                    let dx = dz.matmul_with(&w1m.transpose(), pool);
                    if let Some(t) = tracker {
                        t.free(charge);
                    }
                    acc_param(&mut pgrads, w1, dw1);
                    acc_param(&mut pgrads, w2, dw2);
                    acc_value(&mut vgrads, input, dx);
                }
                Op::Embedding { ids, emb, out } => {
                    let Some(g) = vgrads[out].take() else { continue };
                    let (vr, vc) = (params[emb].rows(), params[emb].cols());
                    let slot = pgrads[emb].get_or_insert_with(|| Mat::zeros(vr, vc));
                    // Fixed ascending-i scatter: deterministic at any
                    // thread count (runs on the caller thread).
                    for (i, &id) in ids.iter().enumerate() {
                        let row = slot.row_mut(id as usize);
                        for (rv, &gv) in row.iter_mut().zip(g.row(i)) {
                            *rv += gv;
                        }
                    }
                }
            }
        }
        let params_out = pgrads
            .into_iter()
            .enumerate()
            .map(|(i, g)| g.unwrap_or_else(|| Mat::zeros(params[i].rows(), params[i].cols())))
            .collect();
        BackwardResult { params: params_out, values: vgrads }
    }
}

fn acc_value(vgrads: &mut [Option<Mat>], id: ValueId, g: Mat) {
    match &mut vgrads[id] {
        None => vgrads[id] = Some(g),
        Some(a) => a.add_assign(&g),
    }
}

fn acc_param(pgrads: &mut [Option<Mat>], id: ParamId, g: Mat) {
    match &mut pgrads[id] {
        None => pgrads[id] = Some(g),
        Some(a) => a.add_assign(&g),
    }
}

/// Gather-scale the projected generators back to row space:
/// `out_i = α_i · g[f(i)]` (dropped rows stay zero) — the dense-side
/// twin of `attention`'s per-tile strip build, materialized once for
/// the MLP's elementwise GELU.
pub fn project_rows(comp: &Compressed, g: &Mat) -> Mat {
    let m = g.cols();
    let mut out = Mat::zeros(comp.b(), m);
    for i in 0..comp.b() {
        let a = comp.alpha[i];
        if a != 0.0 {
            let grow = g.row(comp.assign[i] as usize);
            for (o, &gv) in out.row_mut(i).iter_mut().zip(grow) {
                *o = a * gv;
            }
        }
    }
    out
}

/// tanh-approximation GELU (the GPT-2 form): portable scalar f32, so
/// the activation is bit-identical everywhere by construction.
#[inline]
pub fn gelu(z: f32) -> f32 {
    const C: f32 = 0.797_884_56; // √(2/π)
    const A: f32 = 0.044_715;
    let t = (C * (z + A * z * z * z)).tanh();
    0.5 * z * (1.0 + t)
}

/// Derivative of [`gelu`].
#[inline]
pub fn gelu_grad(z: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    const A: f32 = 0.044_715;
    let u = C * (z + A * z * z * z);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * A * z * z);
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du
}

// ---------------------------------------------------------------------------
// Fused-block forward / backward primitives
// ---------------------------------------------------------------------------

/// Training forward of the fused block on the process-wide pool; see
/// [`qkv_attn_forward_on`].
pub fn qkv_attn_forward(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
) -> (Vec<f32>, QkvAttnSaved) {
    qkv_attn_forward_on(kernels::active(), x, wq, wk, wv, gen_idx, eps, shape, poolx::global(), None)
}

/// Training forward: compress `x`, attend off the compressed
/// representation with statistics. Returns the attention output (the
/// caller's product, not charged) and the saved node. With a ledger,
/// forward transients land in `ledger.forward` and the node's exact
/// byte count is recorded as saved.
#[allow(clippy::too_many_arguments)]
pub fn qkv_attn_forward_on(
    d: Dispatch,
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
    pool: &Pool,
    ledger: Option<&MemoryLedger>,
) -> (Vec<f32>, QkvAttnSaved) {
    assert_eq!(x.rows(), shape.tokens(), "autograd: x rows vs batch·seq");
    let comp = pamm::compress_with(x, gen_idx, eps, pool);
    let (out, lse) = attention::attend_compressed_fwd_on(
        d,
        &comp,
        wq,
        wk,
        wv,
        shape,
        pool,
        ledger.map(|l| &l.forward),
    );
    let saved = QkvAttnSaved { comp, lse, shape: *shape };
    if let Some(l) = ledger {
        l.record_saved(saved.saved_bytes());
    }
    (out, saved)
}

/// Backward of the fused block on the process-wide pool; see
/// [`qkv_attn_backward_on`].
pub fn qkv_attn_backward(
    saved: &QkvAttnSaved,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    out: &[f32],
    dout: &[f32],
    need_dx: bool,
) -> QkvGrads {
    qkv_attn_backward_on(
        kernels::active(),
        saved,
        wq,
        wk,
        wv,
        out,
        dout,
        need_dx,
        poolx::global(),
        None,
    )
}

/// Backward: attention recomputation walk → projection-space gradients
/// → `dW = pamm::grad_w` per weight (+ exact `dX` when `need_dx`).
/// With a ledger, backward transients (recomputed G, the dQ/dK/dV grid
/// buffer, merged projection gradients, the Wᵀ/partial-product
/// temporaries of dX) land in `ledger.backward`; the returned
/// gradients are the caller's product and are not charged.
#[allow(clippy::too_many_arguments)]
pub fn qkv_attn_backward_on(
    d: Dispatch,
    saved: &QkvAttnSaved,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    out: &[f32],
    dout: &[f32],
    need_dx: bool,
    pool: &Pool,
    ledger: Option<&MemoryLedger>,
) -> QkvGrads {
    let shape = &saved.shape;
    let tracker = ledger.map(|l| &l.backward);
    let (dqp, dkp, dvp) = attention::attend_compressed_bwd_on(
        d,
        &saved.comp,
        wq,
        wk,
        wv,
        out,
        dout,
        &saved.lse,
        shape,
        pool,
        tracker,
    );
    let merged_bytes = 3 * shape.tokens() * shape.d_model() * 4;
    if let Some(t) = tracker {
        t.alloc(merged_bytes);
    }
    // dW = β·Ãᵀ·dYᵖ in the gather-scaled Cᵀ·B̃ form — one index
    // accumulate + one k-row GEMM per weight, never a dense b×n
    // contraction (the whole point of the saved Compressed).
    let dwq = pamm::grad_w_with(&saved.comp, &dqp, pool);
    let dwk = pamm::grad_w_with(&saved.comp, &dkp, pool);
    let dwv = pamm::grad_w_with(&saved.comp, &dvp, pool);
    let dx = if need_dx {
        // Exact input gradient: dX = dQᵖ·Wqᵀ + dKᵖ·Wkᵀ + dVᵖ·Wvᵀ. One
        // transposed weight + one partial product live at a time on top
        // of the accumulator; the accumulator itself becomes the
        // returned dx (the caller's product) and is freed here like the
        // other transients once ownership leaves the tracked region.
        let wt_bytes = wq.rows() * wq.cols() * 4;
        let part_bytes = shape.tokens() * wq.rows() * 4;
        let mut dx: Option<Mat> = None;
        for (dyp, w) in [(&dqp, wq), (&dkp, wk), (&dvp, wv)] {
            if let Some(t) = tracker {
                t.alloc(wt_bytes + part_bytes);
            }
            let part = dyp.matmul_with(&w.transpose(), pool);
            match dx.as_mut() {
                None => dx = Some(part), // the accumulator stays charged
                Some(acc) => {
                    acc.add_assign(&part);
                    if let Some(t) = tracker {
                        t.free(part_bytes);
                    }
                }
            }
            if let Some(t) = tracker {
                t.free(wt_bytes);
            }
        }
        if let Some(t) = tracker {
            t.free(part_bytes); // the accumulator leaves as the product
        }
        dx
    } else {
        None
    };
    if let Some(t) = tracker {
        t.free(merged_bytes);
    }
    QkvGrads { dwq, dwk, dwv, dx }
}

/// Mean-squared-error loss and its gradient in one pass:
/// `L = Σ(out−target)² / (2·len)`, `dout = (out−target)/len`. Scalar
/// fixed-order f32 arithmetic — thread- and dispatch-invariant by
/// construction.
pub fn mse_loss(out: &[f32], target: &[f32]) -> (f32, Vec<f32>) {
    assert_eq!(out.len(), target.len(), "mse: length mismatch");
    let n = out.len().max(1) as f32;
    let mut loss = 0.0f32;
    let mut dout = Vec::with_capacity(out.len());
    for (&o, &t) in out.iter().zip(target) {
        let e = o - t;
        loss += e * e;
        dout.push(e / n);
    }
    (loss / (2.0 * n), dout)
}

// ---------------------------------------------------------------------------
// Analytic memory model
// ---------------------------------------------------------------------------

/// Packed-panel bytes one `m×n×k` GEMM can reserve (the exact-growth
/// capacity model of `tensor::kernels`: MR/NR-padded strips of one
/// MC×KC / KC×NC block). Shared with `model`'s whole-net bound. Reads
/// the *runtime* KC/MC/NC ([`kernels::tiles`]) so the bound tracks
/// autotuned tile installs.
pub fn pack_bytes_bound(m: usize, n: usize, k: usize) -> usize {
    let t = kernels::tiles();
    let kc = k.min(t.kc);
    let pa = m.min(t.mc).div_ceil(MR) * MR * kc;
    let pb = n.min(t.nc).div_ceil(NR) * NR * kc;
    4 * (pa + pb)
}

/// Ceiling for the tracked backward-transient peak of
/// [`qkv_attn_backward_on`]:
///
/// * the packed per-task dQ/dK/dV grid buffer (3 Q/K/V tensors — the
///   gradient slabs are genuine outputs of any attention backward),
/// * the three merged projection-gradient matrices,
/// * the recomputed `G = C·W` set + the caller's projection packing,
/// * per-worker backward tile scratch + the apply-stage B̃ (≤ k·d_model
///   per worker) + the apply GEMM packing,
/// * the dX temporaries (one Wᵀ + one partial product) when `need_dx`.
///
/// Sound for the same reason as `attention::fused_peak_bound`: every
/// scratch path grows with `reserve_exact`, so capacities equal the
/// model — and the tracked measurement charges a subset of these
/// terms (see the module docs on the B̃ undermeasure).
///
/// Takes the compression *geometry* (`k` generators over an `n_in`-wide
/// input) rather than a [`Compressed`] — those two numbers are all the
/// bound depends on, so callers never need to rebuild a compression
/// just to evaluate it.
pub fn backward_peak_bound(
    k: usize,
    n_in: usize,
    shape: &AttnShape,
    threads: usize,
    need_dx: bool,
) -> usize {
    let dm = shape.d_model();
    let tokens = shape.tokens();
    let slabs = 3 * shape.tensor_bytes();
    let merged = 3 * tokens * dm * 4;
    let g = 3 * k * dm * 4 + pack_bytes_bound(k, dm, n_in);
    let per_worker = attention::bwd_tile_scratch_bytes(shape.head_dim, shape.seq)
        + k * dm * 4
        + pack_bytes_bound(n_in, dm, k);
    let dx_extra = if need_dx {
        n_in * dm * 4 + tokens * n_in * 4 + threads * pack_bytes_bound(tokens, n_in, dm)
    } else {
        0
    };
    slabs + merged + g + threads * per_worker + dx_extra
}

/// Saved-for-backward bytes of a *dense* autodiff implementation of
/// the same block: the shared projection input X (`tokens × n_in`,
/// saved once per block — the convention of `memory::qkv_saved_bytes`)
/// plus the three materialized Q/K/V tensors the dense flash backward
/// keeps, plus the same O(seq) statistics. This is the baseline the
/// ledger's compression-factor row divides by.
pub fn dense_saved_bytes(n_in: usize, shape: &AttnShape) -> usize {
    shape.tokens() * n_in * 4
        + 3 * shape.tensor_bytes()
        + shape.batch * shape.heads * shape.seq * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Xoshiro256;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        Mat::random_normal(rows, cols, 1.0, &mut rng)
    }

    fn setup(shape: &AttnShape, k: usize, seed: u64) -> (Mat, Mat, Mat, Mat, Vec<usize>) {
        let dm = shape.d_model();
        let x = rand_mat(shape.tokens(), dm, seed);
        let wq = rand_mat(dm, dm, seed + 1);
        let wk = rand_mat(dm, dm, seed + 2);
        let wv = rand_mat(dm, dm, seed + 3);
        let mut rng = Xoshiro256::new(seed + 4);
        let idx = pamm::sample_generators(&mut rng, shape.tokens(), k);
        (x, wq, wk, wv, idx)
    }

    #[test]
    fn forward_output_matches_the_inference_path_bitwise() {
        // The stats-producing training forward must not perturb the
        // numbers of the PR-3 inference forward.
        let shape = AttnShape::new(2, 2, 33, 8, true);
        let (x, wq, wk, wv, idx) = setup(&shape, 10, 70);
        let pool = Pool::serial();
        let (_, want) = attention::pamm_qkv_attention_with(
            &x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool,
        );
        let (out, saved) = qkv_attn_forward_on(
            kernels::active(),
            &x,
            &wq,
            &wk,
            &wv,
            &idx,
            Eps::Inf,
            &shape,
            &pool,
            None,
        );
        assert_eq!(out, want);
        assert_eq!(saved.lse.len(), shape.batch * shape.heads * shape.seq);
        assert_eq!(saved.saved_bytes(), saved.comp.stored_bytes() + saved.lse.len() * 4);
    }

    #[test]
    fn graph_tape_records_inventory_and_backprops_through_tied_weights() {
        // embedding → tied head → xent: the tied parameter must receive
        // BOTH the head's dense contribution and the embedding scatter.
        let vocab = 11usize;
        let dm = 6usize;
        let emb = rand_mat(vocab, dm, 100);
        // Distinct ids: each embedding row receives exactly one scatter
        // add, so tied == head + scatter holds BITWISE below (repeated
        // ids would reassociate the f32 sums).
        let ids: Vec<i32> = vec![3, 7, 0, 10, 4];
        let targets: Vec<i32> = vec![7, 0, 10, 3, 1];
        let pool = Pool::serial();
        let mut tape = Tape::new();
        let (x, xid) = tape.embedding(&emb, 0, &ids, None);
        let (logits, lid) = tape.tied_head(&x, xid, &emb, 0, &pool, None);
        let loss = tape.softmax_xent(&logits, lid, &targets, None);
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(tape.len(), 3);
        let inv = tape.saved_inventory();
        assert_eq!(inv[0], ("embedding", ids.len() * 4));
        assert_eq!(inv[1].0, "tied_head");
        assert_eq!(inv[2].0, "softmax_xent");
        assert_eq!(tape.saved_bytes(), inv.iter().map(|(_, b)| b).sum::<usize>());

        let params = vec![emb.clone()];
        let res = tape.backward(kernels::active(), &params, &pool, None);
        assert_eq!(res.params.len(), 1);
        let demb = &res.params[0];
        assert_eq!((demb.rows(), demb.cols()), (vocab, dm));
        // Split the two tied contributions apart by rebuilding the same
        // graph with the embedding bound to a DIFFERENT param id: param
        // 0 then carries only the head term, param 1 only the scatter.
        let mut tape3 = Tape::new();
        let (x3, x3id) = tape3.embedding(&emb, 1, &ids, None);
        let (lg3, lg3id) = tape3.tied_head(&x3, x3id, &emb, 0, &pool, None);
        let _ = tape3.softmax_xent(&lg3, lg3id, &targets, None);
        let res3 = tape3.backward(kernels::active(), &[emb.clone(), emb.clone()], &pool, None);
        let head_only = &res3.params[0];
        let scatter_only = &res3.params[1];
        // Tied gradient == head term + scatter term, bitwise (fixed
        // accumulation order: head first, then ascending-i scatter).
        let mut sum = head_only.clone();
        sum.add_assign(scatter_only);
        assert_eq!(demb, &sum, "tied gradient must be the exact sum of both paths");
        // Rows never referenced by ids get no scatter.
        assert!(scatter_only.row(5).iter().all(|&v| v == 0.0));
        assert!(scatter_only.row(3).iter().any(|&v| v != 0.0));
    }

    #[test]
    fn residual_fans_the_gradient_out_and_layernorm_grads_are_finite() {
        let rows = 7usize;
        let n = 5usize;
        let x = rand_mat(rows, n, 200);
        let gain = Mat::from_vec(1, n, vec![1.0; n]);
        let bias = Mat::zeros(1, n);
        let pool = Pool::serial();
        let mut tape = Tape::new();
        let xid = tape.leaf();
        let (y, yid) = tape.layer_norm(&x, xid, &gain, 0, &bias, 1, None);
        // Layernorm output rows are standardized: mean ≈ 0, var ≈ 1.
        for i in 0..rows {
            let m: f32 = y.row(i).iter().sum::<f32>() / n as f32;
            assert!(m.abs() < 1e-5, "row {i} mean {m}");
        }
        let (z, zid) = tape.residual(&x, xid, &y, yid, None);
        assert_eq!(z.get(0, 0), x.get(0, 0) + y.get(0, 0));
        let seed = rand_mat(rows, n, 201);
        tape.seed(zid, seed.clone());
        let params = vec![gain.clone(), bias.clone()];
        let res = tape.backward(kernels::active(), &params, &pool, None);
        // dbias = column sums of the layernorm's upstream grad (= seed).
        let mut want_db = vec![0f32; n];
        for i in 0..rows {
            for j in 0..n {
                want_db[j] += seed.get(i, j);
            }
        }
        for j in 0..n {
            assert!((res.params[1].get(0, j) - want_db[j]).abs() < 1e-5);
        }
        // The leaf grad is residual-pass-through + layernorm dx.
        let dx = res.values[xid].as_ref().expect("leaf grad");
        assert_eq!((dx.rows(), dx.cols()), (rows, n));
        assert!(dx.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gelu_matches_finite_difference() {
        for &z in &[-3.0f32, -1.0, -0.1, 0.0, 0.2, 1.5, 4.0] {
            let h = 1e-3f64;
            let f = |v: f64| {
                let c = 0.7978845608f64;
                let a = 0.044715f64;
                0.5 * v * (1.0 + (c * (v + a * v * v * v)).tanh())
            };
            let fd = ((f(z as f64 + h) - f(z as f64 - h)) / (2.0 * h)) as f32;
            assert!(
                (gelu_grad(z) - fd).abs() < 1e-3,
                "z={z}: grad {} vs fd {fd}",
                gelu_grad(z)
            );
            assert!((gelu(z) - f(z as f64) as f32).abs() < 1e-5);
        }
        assert_eq!(gelu(0.0), 0.0);
    }

    #[test]
    fn softmax_xent_loss_and_gradient() {
        // Uniform logits: loss = ln(vocab), grad rows sum to 0 and the
        // target entry is (1/vocab − 1)/rows.
        let (rows, vocab) = (4usize, 8usize);
        let logits = Mat::zeros(rows, vocab);
        let targets: Vec<i32> = vec![0, 3, 7, 2];
        let mut tape = Tape::new();
        let lid = tape.leaf();
        let loss = tape.softmax_xent(&logits, lid, &targets, None);
        assert!((loss - (vocab as f32).ln()).abs() < 1e-5, "{loss}");
        let Op::SoftmaxXent { dlogits, .. } = &tape.nodes()[0] else { panic!("xent node") };
        for i in 0..rows {
            let s: f32 = dlogits.row(i).iter().sum();
            assert!(s.abs() < 1e-6, "row {i} grad sum {s}");
            let want = (1.0 / vocab as f32 - 1.0) / rows as f32;
            assert!((dlogits.get(i, targets[i] as usize) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn mse_loss_and_gradient() {
        let out = [1.0f32, 2.0, 3.0];
        let tgt = [1.0f32, 1.0, 5.0];
        let (loss, dout) = mse_loss(&out, &tgt);
        // L = (0 + 1 + 4) / 6, d = e/3.
        assert!((loss - 5.0 / 6.0).abs() < 1e-6);
        assert_eq!(dout.len(), 3);
        assert!((dout[1] - 1.0 / 3.0).abs() < 1e-7);
        assert!((dout[2] + 2.0 / 3.0).abs() < 1e-7);
    }

    #[test]
    fn saved_bytes_beat_the_dense_baseline() {
        let shape = AttnShape::new(2, 2, 128, 16, true);
        let (x, wq, wk, wv, idx) = setup(&shape, 8, 90);
        let pool = Pool::serial();
        let ledger = MemoryLedger::new();
        let (_, saved) = qkv_attn_forward_on(
            kernels::active(),
            &x,
            &wq,
            &wk,
            &wv,
            &idx,
            Eps::Inf,
            &shape,
            &pool,
            Some(&ledger),
        );
        assert_eq!(ledger.saved(), saved.saved_bytes());
        let dense = dense_saved_bytes(shape.d_model(), &shape);
        // At k = 8 of 256 tokens the saved set must undercut the dense
        // baseline by a wide margin (the factor row of the ledger).
        assert!(
            saved.saved_bytes() * 4 < dense,
            "saved {} vs dense {dense}",
            saved.saved_bytes()
        );
    }

    #[test]
    fn project_rows_matches_reconstruct_then_matmul() {
        let a = rand_mat(24, 8, 300);
        let w = rand_mat(8, 5, 301);
        let mut rng = Xoshiro256::new(302);
        let idx = pamm::sample_generators(&mut rng, 24, 6);
        let comp = pamm::compress_with(&a, &idx, Eps::Val(0.7), &Pool::serial());
        let g = comp.project_generators(&w);
        let got = project_rows(&comp, &g);
        let want = comp.reconstruct().matmul(&w);
        assert!(got.max_abs_diff(&want) <= 1e-4 * want.frob_norm().max(1.0));
    }
}
