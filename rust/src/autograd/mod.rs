//! Minimal reverse-mode autograd for the native hot path — the
//! compressed-activation training step of the paper, end to end in
//! Rust (DESIGN.md §6).
//!
//! The paper's headline is a *training*-memory claim: the Q/K/V
//! projection activations are stored PAMM-compressed in the forward
//! pass and only approximately reconstructed in the backward to form
//! weight gradients. PRs 1–3 built the forward ( `pamm::compress`,
//! `attention::pamm_qkv_attention`); this module closes the loop with
//! a backward that *consumes* the compressed residuals:
//!
//! * **Forward** ([`qkv_attn_forward`]): compress `x`, attend straight
//!   off the [`Compressed`] representation with softmax statistics —
//!   what gets pushed on the [`Tape`] is **only** the `Compressed`
//!   struct plus the per-row log-sum-exp (O(seq) per head). No dense
//!   activation is ever saved.
//! * **Backward** ([`qkv_attn_backward`]): FlashAttention-2-style
//!   recomputation (`attention::attend_compressed_bwd_on`) rebuilds
//!   Q/K/V strips per tile from the recomputed `G = C·W`, yields the
//!   projection-space gradients, and the weight gradients follow as
//!   the gather-scaled `dW = β·Cᵀ·B̃` of [`pamm::grad_w`] — the
//!   `Ãᵀ·dY` form, never a dense `b×n` residual contraction. `dα` and
//!   `d(assign)` are treated straight-through (constants of the
//!   forward), exactly like the JAX custom-vjp in
//!   `python/compile/pamm_layer.py`. The input gradient `dX = Σ
//!   dYᵖ·Wᵀ` is exact (W is a parameter, stored regardless).
//!
//! # Determinism
//!
//! Every stage routes through `tensor::kernels` (no-FMA
//! scalar==sse2==avx2 bit-identity) and partitions work only over the
//! (batch·head) grid / output rows / output columns on `poolx` — so
//! loss, gradients and the updated weights are **bit-identical at any
//! thread count and at every dispatch level**
//! (`rust/tests/prop_backward.rs`).
//!
//! # Memory ledger
//!
//! A tracked step fills a [`MemoryLedger`]: forward transients, the
//! exact saved-for-backward total ([`QkvAttnSaved::saved_bytes`] =
//! `Compressed::stored_bytes()` + statistics), and backward transients
//! — the backward peak asserted against the analytic
//! [`backward_peak_bound`], and the saved total against
//! [`dense_saved_bytes`], the bytes a dense-autodiff implementation of
//! the same block would keep between forward and backward (X + the
//! three Q/K/V tensors + the same statistics). Known undermeasure: the
//! per-worker B̃ scratch growth inside `pamm::grad_w` is not plumbed to
//! the tracker (it is covered by the bound's B̃ term); everything else
//! the backward allocates is charged.

use crate::attention::{self, AttnShape};
use crate::memory::MemoryLedger;
use crate::pamm::{self, Compressed, Eps};
use crate::poolx::{self, Pool};
use crate::tensor::kernels::{self, Dispatch, KC, MC, MR, NC, NR};
use crate::tensor::Mat;

/// Saved-for-backward state of one fused PAMM-QKV + flash-attention
/// block: the compressed projection input and the O(seq) softmax
/// statistics — nothing else. This struct *is* the paper's memory
/// story: `stored_bytes + 4·(batch·heads·seq)` versus the dense
/// `X + Q + K + V` set of an uncompressed autodiff.
#[derive(Debug, Clone)]
pub struct QkvAttnSaved {
    pub comp: Compressed,
    /// Per-row log-sum-exp of the softmax, task-major
    /// (`batch·heads·seq` f32) — FlashAttention-2's backward residual.
    pub lse: Vec<f32>,
    pub shape: AttnShape,
}

impl QkvAttnSaved {
    /// Exact bytes this node keeps live between forward and backward.
    pub fn saved_bytes(&self) -> usize {
        self.comp.stored_bytes() + self.lse.len() * 4
    }
}

/// Gradients of one fused block. `dx` is present only when requested
/// (`need_dx`): the last layer of a net feeds no one below it.
#[derive(Debug)]
pub struct QkvGrads {
    pub dwq: Mat,
    pub dwk: Mat,
    pub dwv: Mat,
    pub dx: Option<Mat>,
}

/// Minimal reverse-mode tape: the forward pushes one saved node per
/// differentiable block, the backward pops in reverse order. Only the
/// hot-path op exists (the PAMM-compressed QKV projection fused with
/// flash attention); a multi-layer model is N pushes and N pops, and
/// [`Tape::saved_bytes`] is the whole-net saved-for-backward figure
/// the ledger records.
#[derive(Debug, Default)]
pub struct Tape {
    nodes: Vec<QkvAttnSaved>,
}

impl Tape {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, saved: QkvAttnSaved) {
        self.nodes.push(saved);
    }

    /// Pop the most recent node — backward consumes the tape in
    /// reverse push order.
    pub fn pop(&mut self) -> Option<QkvAttnSaved> {
        self.nodes.pop()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Total saved-for-backward bytes currently held by the tape.
    pub fn saved_bytes(&self) -> usize {
        self.nodes.iter().map(|n| n.saved_bytes()).sum()
    }
}

// ---------------------------------------------------------------------------
// Forward / backward
// ---------------------------------------------------------------------------

/// Training forward of the fused block on the process-wide pool; see
/// [`qkv_attn_forward_on`].
pub fn qkv_attn_forward(
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
) -> (Vec<f32>, QkvAttnSaved) {
    qkv_attn_forward_on(kernels::active(), x, wq, wk, wv, gen_idx, eps, shape, poolx::global(), None)
}

/// Training forward: compress `x`, attend off the compressed
/// representation with statistics. Returns the attention output (the
/// caller's product, not charged) and the saved node. With a ledger,
/// forward transients land in `ledger.forward` and the node's exact
/// byte count is recorded as saved.
#[allow(clippy::too_many_arguments)]
pub fn qkv_attn_forward_on(
    d: Dispatch,
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    gen_idx: &[usize],
    eps: Eps,
    shape: &AttnShape,
    pool: &Pool,
    ledger: Option<&MemoryLedger>,
) -> (Vec<f32>, QkvAttnSaved) {
    assert_eq!(x.rows(), shape.tokens(), "autograd: x rows vs batch·seq");
    let comp = pamm::compress_with(x, gen_idx, eps, pool);
    let (out, lse) = attention::attend_compressed_fwd_on(
        d,
        &comp,
        wq,
        wk,
        wv,
        shape,
        pool,
        ledger.map(|l| &l.forward),
    );
    let saved = QkvAttnSaved { comp, lse, shape: *shape };
    if let Some(l) = ledger {
        l.record_saved(saved.saved_bytes());
    }
    (out, saved)
}

/// Backward of the fused block on the process-wide pool; see
/// [`qkv_attn_backward_on`].
pub fn qkv_attn_backward(
    saved: &QkvAttnSaved,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    out: &[f32],
    dout: &[f32],
    need_dx: bool,
) -> QkvGrads {
    qkv_attn_backward_on(
        kernels::active(),
        saved,
        wq,
        wk,
        wv,
        out,
        dout,
        need_dx,
        poolx::global(),
        None,
    )
}

/// Backward: attention recomputation walk → projection-space gradients
/// → `dW = pamm::grad_w` per weight (+ exact `dX` when `need_dx`).
/// With a ledger, backward transients (recomputed G, the dQ/dK/dV grid
/// buffer, merged projection gradients, the Wᵀ/partial-product
/// temporaries of dX) land in `ledger.backward`; the returned
/// gradients are the caller's product and are not charged.
#[allow(clippy::too_many_arguments)]
pub fn qkv_attn_backward_on(
    d: Dispatch,
    saved: &QkvAttnSaved,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    out: &[f32],
    dout: &[f32],
    need_dx: bool,
    pool: &Pool,
    ledger: Option<&MemoryLedger>,
) -> QkvGrads {
    let shape = &saved.shape;
    let tracker = ledger.map(|l| &l.backward);
    let (dqp, dkp, dvp) = attention::attend_compressed_bwd_on(
        d,
        &saved.comp,
        wq,
        wk,
        wv,
        out,
        dout,
        &saved.lse,
        shape,
        pool,
        tracker,
    );
    let merged_bytes = 3 * shape.tokens() * shape.d_model() * 4;
    if let Some(t) = tracker {
        t.alloc(merged_bytes);
    }
    // dW = β·Ãᵀ·dYᵖ in the gather-scaled Cᵀ·B̃ form — one index
    // accumulate + one k-row GEMM per weight, never a dense b×n
    // contraction (the whole point of the saved Compressed).
    let dwq = pamm::grad_w_with(&saved.comp, &dqp, pool);
    let dwk = pamm::grad_w_with(&saved.comp, &dkp, pool);
    let dwv = pamm::grad_w_with(&saved.comp, &dvp, pool);
    let dx = if need_dx {
        // Exact input gradient: dX = dQᵖ·Wqᵀ + dKᵖ·Wkᵀ + dVᵖ·Wvᵀ. One
        // transposed weight + one partial product live at a time on top
        // of the accumulator; the accumulator itself becomes the
        // returned dx (the caller's product) and is freed here like the
        // other transients once ownership leaves the tracked region.
        let wt_bytes = wq.rows() * wq.cols() * 4;
        let part_bytes = shape.tokens() * wq.rows() * 4;
        let mut dx: Option<Mat> = None;
        for (dyp, w) in [(&dqp, wq), (&dkp, wk), (&dvp, wv)] {
            if let Some(t) = tracker {
                t.alloc(wt_bytes + part_bytes);
            }
            let part = dyp.matmul_with(&w.transpose(), pool);
            match dx.as_mut() {
                None => dx = Some(part), // the accumulator stays charged
                Some(acc) => {
                    acc.add_assign(&part);
                    if let Some(t) = tracker {
                        t.free(part_bytes);
                    }
                }
            }
            if let Some(t) = tracker {
                t.free(wt_bytes);
            }
        }
        if let Some(t) = tracker {
            t.free(part_bytes); // the accumulator leaves as the product
        }
        dx
    } else {
        None
    };
    if let Some(t) = tracker {
        t.free(merged_bytes);
    }
    QkvGrads { dwq, dwk, dwv, dx }
}

/// Mean-squared-error loss and its gradient in one pass:
/// `L = Σ(out−target)² / (2·len)`, `dout = (out−target)/len`. Scalar
/// fixed-order f32 arithmetic — thread- and dispatch-invariant by
/// construction.
pub fn mse_loss(out: &[f32], target: &[f32]) -> (f32, Vec<f32>) {
    assert_eq!(out.len(), target.len(), "mse: length mismatch");
    let n = out.len().max(1) as f32;
    let mut loss = 0.0f32;
    let mut dout = Vec::with_capacity(out.len());
    for (&o, &t) in out.iter().zip(target) {
        let e = o - t;
        loss += e * e;
        dout.push(e / n);
    }
    (loss / (2.0 * n), dout)
}

// ---------------------------------------------------------------------------
// Analytic memory model
// ---------------------------------------------------------------------------

/// Packed-panel bytes one `m×n×k` GEMM can reserve (the exact-growth
/// capacity model of `tensor::kernels`: MR/NR-padded strips of one
/// MC×KC / KC×NC block).
fn pack_bytes_bound(m: usize, n: usize, k: usize) -> usize {
    let kc = k.min(KC);
    let pa = m.min(MC).div_ceil(MR) * MR * kc;
    let pb = n.min(NC).div_ceil(NR) * NR * kc;
    4 * (pa + pb)
}

/// Ceiling for the tracked backward-transient peak of
/// [`qkv_attn_backward_on`]:
///
/// * the packed per-task dQ/dK/dV grid buffer (3 Q/K/V tensors — the
///   gradient slabs are genuine outputs of any attention backward),
/// * the three merged projection-gradient matrices,
/// * the recomputed `G = C·W` set + the caller's projection packing,
/// * per-worker backward tile scratch + the apply-stage B̃ (≤ k·d_model
///   per worker) + the apply GEMM packing,
/// * the dX temporaries (one Wᵀ + one partial product) when `need_dx`.
///
/// Sound for the same reason as `attention::fused_peak_bound`: every
/// scratch path grows with `reserve_exact`, so capacities equal the
/// model — and the tracked measurement charges a subset of these
/// terms (see the module docs on the B̃ undermeasure).
///
/// Takes the compression *geometry* (`k` generators over an `n_in`-wide
/// input) rather than a [`Compressed`] — those two numbers are all the
/// bound depends on, so callers never need to rebuild a compression
/// just to evaluate it.
pub fn backward_peak_bound(
    k: usize,
    n_in: usize,
    shape: &AttnShape,
    threads: usize,
    need_dx: bool,
) -> usize {
    let dm = shape.d_model();
    let tokens = shape.tokens();
    let slabs = 3 * shape.tensor_bytes();
    let merged = 3 * tokens * dm * 4;
    let g = 3 * k * dm * 4 + pack_bytes_bound(k, dm, n_in);
    let per_worker = attention::bwd_tile_scratch_bytes(shape.head_dim, shape.seq)
        + k * dm * 4
        + pack_bytes_bound(n_in, dm, k);
    let dx_extra = if need_dx {
        n_in * dm * 4 + tokens * n_in * 4 + threads * pack_bytes_bound(tokens, n_in, dm)
    } else {
        0
    };
    slabs + merged + g + threads * per_worker + dx_extra
}

/// Saved-for-backward bytes of a *dense* autodiff implementation of
/// the same block: the shared projection input X (`tokens × n_in`,
/// saved once per block — the convention of `memory::qkv_saved_bytes`)
/// plus the three materialized Q/K/V tensors the dense flash backward
/// keeps, plus the same O(seq) statistics. This is the baseline the
/// ledger's compression-factor row divides by.
pub fn dense_saved_bytes(n_in: usize, shape: &AttnShape) -> usize {
    shape.tokens() * n_in * 4
        + 3 * shape.tensor_bytes()
        + shape.batch * shape.heads * shape.seq * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Xoshiro256;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        Mat::random_normal(rows, cols, 1.0, &mut rng)
    }

    fn setup(shape: &AttnShape, k: usize, seed: u64) -> (Mat, Mat, Mat, Mat, Vec<usize>) {
        let dm = shape.d_model();
        let x = rand_mat(shape.tokens(), dm, seed);
        let wq = rand_mat(dm, dm, seed + 1);
        let wk = rand_mat(dm, dm, seed + 2);
        let wv = rand_mat(dm, dm, seed + 3);
        let mut rng = Xoshiro256::new(seed + 4);
        let idx = pamm::sample_generators(&mut rng, shape.tokens(), k);
        (x, wq, wk, wv, idx)
    }

    #[test]
    fn forward_output_matches_the_inference_path_bitwise() {
        // The stats-producing training forward must not perturb the
        // numbers of the PR-3 inference forward.
        let shape = AttnShape::new(2, 2, 33, 8, true);
        let (x, wq, wk, wv, idx) = setup(&shape, 10, 70);
        let pool = Pool::serial();
        let (_, want) = attention::pamm_qkv_attention_with(
            &x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool,
        );
        let (out, saved) = qkv_attn_forward_on(
            kernels::active(),
            &x,
            &wq,
            &wk,
            &wv,
            &idx,
            Eps::Inf,
            &shape,
            &pool,
            None,
        );
        assert_eq!(out, want);
        assert_eq!(saved.lse.len(), shape.batch * shape.heads * shape.seq);
        assert_eq!(saved.saved_bytes(), saved.comp.stored_bytes() + saved.lse.len() * 4);
    }

    #[test]
    fn tape_pushes_and_pops_in_reverse() {
        let shape = AttnShape::new(1, 1, 8, 4, false);
        let (x, wq, wk, wv, idx) = setup(&shape, 3, 80);
        let pool = Pool::serial();
        let mut tape = Tape::new();
        assert!(tape.is_empty());
        let (_, s1) =
            qkv_attn_forward_on(kernels::active(), &x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool, None);
        let b1 = s1.saved_bytes();
        tape.push(s1);
        let (_, s2) =
            qkv_attn_forward_on(kernels::active(), &x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool, None);
        let b2 = s2.saved_bytes();
        tape.push(s2);
        assert_eq!(tape.len(), 2);
        assert_eq!(tape.saved_bytes(), b1 + b2);
        assert_eq!(tape.pop().map(|n| n.saved_bytes()), Some(b2), "LIFO order");
        assert_eq!(tape.pop().map(|n| n.saved_bytes()), Some(b1));
        assert!(tape.pop().is_none());
    }

    #[test]
    fn mse_loss_and_gradient() {
        let out = [1.0f32, 2.0, 3.0];
        let tgt = [1.0f32, 1.0, 5.0];
        let (loss, dout) = mse_loss(&out, &tgt);
        // L = (0 + 1 + 4) / 6, d = e/3.
        assert!((loss - 5.0 / 6.0).abs() < 1e-6);
        assert_eq!(dout.len(), 3);
        assert!((dout[1] - 1.0 / 3.0).abs() < 1e-7);
        assert!((dout[2] + 2.0 / 3.0).abs() < 1e-7);
    }

    #[test]
    fn saved_bytes_beat_the_dense_baseline() {
        let shape = AttnShape::new(2, 2, 128, 16, true);
        let (x, wq, wk, wv, idx) = setup(&shape, 8, 90);
        let pool = Pool::serial();
        let ledger = MemoryLedger::new();
        let (_, saved) = qkv_attn_forward_on(
            kernels::active(),
            &x,
            &wq,
            &wk,
            &wv,
            &idx,
            Eps::Inf,
            &shape,
            &pool,
            Some(&ledger),
        );
        assert_eq!(ledger.saved(), saved.saved_bytes());
        let dense = dense_saved_bytes(shape.d_model(), &shape);
        // At k = 8 of 256 tokens the saved set must undercut the dense
        // baseline by a wide margin (the factor row of the ledger).
        assert!(
            saved.saved_bytes() * 4 < dense,
            "saved {} vs dense {dense}",
            saved.saved_bytes()
        );
    }
}
