//! Property tests for the native fine-tuning path (`model` with the
//! classification head + `coordinator::FtTrainer` over the multi-op
//! graph tape):
//!
//! * f64 finite-difference gradient check through the whole
//!   classification stack — two transformer blocks, final LN, mean
//!   pool, linear head, label cross-entropy (all-generators, so the
//!   compressed forward is the dense function the oracle
//!   differentiates),
//! * scalar==sse2==avx2 bit-equality of the fine-tune loss and every
//!   gradient (head included),
//! * 1/2/4-thread parity of whole fine-tuning trajectories,
//! * checkpoint round-trip + resume: a save/reload/continue
//!   fine-tuning run is bit-identical, step for step, to an
//!   uninterrupted one — dev evaluation included.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both). Mirrors `prop_model.rs` through the LM trunk; the
//! classification tail (mean pool → linear head → label xent) is the
//! part only this suite covers.

use pamm::autograd::LN_EPS;
use pamm::coordinator::{find_task, ft_param_names, FtTrainer, NativeOpt};
use pamm::data::glue::{LabeledStream, TaskCorpus};
use pamm::model::{self, LmConfig, TransformerLM};
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::{self, Dispatch};
use pamm::tensor::Mat;

fn rand_mat(rows: usize, cols: usize, std: f32, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    Mat::random_normal(rows, cols, std, &mut rng)
}

/// A two-block test model + classification head with weights large
/// enough that every parameter group gets a well-sized gradient (same
/// inflation scheme as `prop_model.rs::fd_model`).
fn fd_cls_model(cfg: &LmConfig, n_classes: usize, seed: u64) -> TransformerLM {
    let mut m = TransformerLM::new(cfg.clone(), seed);
    let dm = cfg.d_model();
    let mut s = seed;
    let mut next = |rows: usize, cols: usize, std: f32| {
        s += 1;
        rand_mat(rows, cols, std, s)
    };
    m.params[0] = next(cfg.vocab, dm, 0.5); // emb
    for b in 0..cfg.n_layers {
        let p = 1 + b * model::PARAMS_PER_BLOCK;
        let mut g = next(1, dm, 0.2);
        for v in g.data_mut() {
            *v += 1.0; // gains near 1, not 0
        }
        m.params[p] = g;
        m.params[p + 1] = next(1, dm, 0.1);
        m.params[p + 2] = next(dm, dm, 0.4);
        m.params[p + 3] = next(dm, dm, 0.4);
        m.params[p + 4] = next(dm, dm, 0.4);
        let mut g2 = next(1, dm, 0.2);
        for v in g2.data_mut() {
            *v += 1.0;
        }
        m.params[p + 5] = g2;
        m.params[p + 6] = next(1, dm, 0.1);
        m.params[p + 7] = next(dm, cfg.d_ff, 0.4);
        m.params[p + 8] = next(cfg.d_ff, dm, 0.4);
    }
    let lnf = 1 + cfg.n_layers * model::PARAMS_PER_BLOCK;
    let mut gf = next(1, dm, 0.2);
    for v in gf.data_mut() {
        *v += 1.0;
    }
    m.params[lnf] = gf;
    m.params[lnf + 1] = next(1, dm, 0.1);
    m.params.push(next(dm, n_classes, 0.4)); // classification head
    m
}

/// Classification forward + backward through the tape: the fine-tune
/// gradient (every LM parameter + the head), all-generators.
#[allow(clippy::too_many_arguments)]
fn cls_loss_and_grads(
    m: &TransformerLM,
    d: Dispatch,
    ids: &[i32],
    labels: &[i32],
    batch: usize,
    seq: usize,
    k: usize,
    rng_seed: u64,
    pool: &Pool,
) -> (f32, Vec<Mat>) {
    let mut rng = Xoshiro256::new(rng_seed);
    let (loss, tape) =
        m.forward_classify(d, ids, labels, batch, seq, k, Eps::Inf, &mut rng, pool, None);
    let res = tape.backward(d, &m.params, pool, None);
    (loss, res.params)
}

// ---------------------------------------------------------------------------
// f64 oracle — an independent dense implementation of the whole
// classification stack (trunk helpers identical to prop_model.rs)
// ---------------------------------------------------------------------------

fn mm64(a: &[f64], b: &[f64], r: usize, k: usize, c: usize) -> Vec<f64> {
    let mut out = vec![0f64; r * c];
    for i in 0..r {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..c {
                out[i * c + j] += av * b[p * c + j];
            }
        }
    }
    out
}

fn ln64(x: &[f64], rows: usize, n: usize, g: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0f64; rows * n];
    for i in 0..rows {
        let xr = &x[i * n..(i + 1) * n];
        let mu: f64 = xr.iter().sum::<f64>() / n as f64;
        let var: f64 = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / n as f64;
        let r = 1.0 / (var + LN_EPS as f64).sqrt();
        for j in 0..n {
            out[i * n + j] = (xr[j] - mu) * r * g[j] + b[j];
        }
    }
    out
}

fn gelu64(z: f64) -> f64 {
    let c = 0.7978845608028654f64; // √(2/π)
    let a = 0.044715f64;
    0.5 * z * (1.0 + (c * (z + a * z * z * z)).tanh())
}

/// Dense causal multi-head attention, token-major in and out.
fn attn64(
    qp: &[f64],
    kp: &[f64],
    vp: &[f64],
    batch: usize,
    seq: usize,
    heads: usize,
    dh: usize,
) -> Vec<f64> {
    let dm = heads * dh;
    let scale = 1.0 / (dh as f64).sqrt();
    let mut out = vec![0f64; batch * seq * dm];
    for b in 0..batch {
        for h in 0..heads {
            for i in 0..seq {
                let ri = (b * seq + i) * dm + h * dh;
                let mut scores = vec![0f64; i + 1];
                for (j, s) in scores.iter_mut().enumerate() {
                    let rj = (b * seq + j) * dm + h * dh;
                    let mut acc = 0f64;
                    for c in 0..dh {
                        acc += qp[ri + c] * kp[rj + c];
                    }
                    *s = scale * acc;
                }
                let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut sum = 0f64;
                for s in scores.iter_mut() {
                    *s = (*s - mx).exp();
                    sum += *s;
                }
                for c in 0..dh {
                    let mut acc = 0f64;
                    for (j, p) in scores.iter().enumerate() {
                        let rj = (b * seq + j) * dm + h * dh;
                        acc += p * vp[rj + c];
                    }
                    out[ri + c] = acc / sum;
                }
            }
        }
    }
    out
}

/// The whole classification stack in f64, dense: trunk (embedding →
/// blocks → final LN) → sequence mean pool → linear head → label
/// cross-entropy averaged over the batch.
fn oracle_cls_loss(
    cfg: &LmConfig,
    params: &[Vec<f64>],
    n_classes: usize,
    ids: &[i32],
    labels: &[i32],
    batch: usize,
    seq: usize,
) -> f64 {
    let dm = cfg.d_model();
    let tokens = batch * seq;
    let emb = &params[0];
    let mut x = vec![0f64; tokens * dm];
    for (i, &id) in ids.iter().enumerate() {
        x[i * dm..(i + 1) * dm].copy_from_slice(&emb[id as usize * dm..(id as usize + 1) * dm]);
    }
    for b in 0..cfg.n_layers {
        let p = 1 + b * model::PARAMS_PER_BLOCK;
        let h1 = ln64(&x, tokens, dm, &params[p], &params[p + 1]);
        let qp = mm64(&h1, &params[p + 2], tokens, dm, dm);
        let kp = mm64(&h1, &params[p + 3], tokens, dm, dm);
        let vp = mm64(&h1, &params[p + 4], tokens, dm, dm);
        let attn = attn64(&qp, &kp, &vp, batch, seq, cfg.heads, cfg.head_dim);
        for (xv, av) in x.iter_mut().zip(&attn) {
            *xv += av;
        }
        let h2 = ln64(&x, tokens, dm, &params[p + 5], &params[p + 6]);
        let mut z = mm64(&h2, &params[p + 7], tokens, dm, cfg.d_ff);
        for v in z.iter_mut() {
            *v = gelu64(*v);
        }
        let y = mm64(&z, &params[p + 8], tokens, cfg.d_ff, dm);
        for (xv, yv) in x.iter_mut().zip(&y) {
            *xv += yv;
        }
    }
    let lnf = 1 + cfg.n_layers * model::PARAMS_PER_BLOCK;
    let hf = ln64(&x, tokens, dm, &params[lnf], &params[lnf + 1]);
    // Sequence mean pool: one d_model row per example.
    let mut pooled = vec![0f64; batch * dm];
    for b in 0..batch {
        for t in 0..seq {
            for j in 0..dm {
                pooled[b * dm + j] += hf[(b * seq + t) * dm + j];
            }
        }
        for j in 0..dm {
            pooled[b * dm + j] /= seq as f64;
        }
    }
    // Linear head + per-example softmax cross-entropy, batch-averaged.
    let w = &params[cfg.n_params()];
    let logits = mm64(&pooled, w, batch, dm, n_classes);
    let mut loss = 0f64;
    for b in 0..batch {
        let lr = &logits[b * n_classes..(b + 1) * n_classes];
        let mx = lr.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + lr.iter().map(|l| (l - mx).exp()).sum::<f64>().ln();
        loss += lse - lr[labels[b] as usize];
    }
    loss / batch as f64
}

#[test]
fn finite_difference_gradient_check_through_the_classification_head() {
    let cfg = LmConfig { vocab: 17, n_layers: 2, heads: 2, head_dim: 3, d_ff: 10 };
    let n_classes = 3usize;
    let (batch, seq) = (2usize, 4usize);
    let tokens = batch * seq;
    let m = fd_cls_model(&cfg, n_classes, 11000);
    let mut rng = Xoshiro256::new(11100);
    let ids: Vec<i32> = (0..tokens).map(|_| rng.next_below(cfg.vocab as u64) as i32).collect();
    let labels: Vec<i32> = (0..batch).map(|_| rng.next_below(n_classes as u64) as i32).collect();
    let pool = Pool::serial();

    // All generators: the compression is the identity up to Lemma-1 α
    // rounding (≈1e-7) — the analytic gradients are exact for the
    // dense function the oracle computes.
    let k = tokens;
    let (loss, grads) =
        cls_loss_and_grads(&m, kernels::active(), &ids, &labels, batch, seq, k, 11200, &pool);
    let params64: Vec<Vec<f64>> =
        m.params.iter().map(|p| p.data().iter().map(|&v| v as f64).collect()).collect();
    let oracle = oracle_cls_loss(&cfg, &params64, n_classes, &ids, &labels, batch, seq);
    assert!(
        (loss as f64 - oracle).abs() < 1e-3 * oracle.abs().max(1.0),
        "forward mismatch: native {loss} vs oracle {oracle}"
    );

    let h = 1e-3f64;
    let mut w64 = params64;
    let names = ft_param_names(&cfg);
    for (pi, name) in names.iter().enumerate() {
        let n_entries = w64[pi].len();
        let mut fds = Vec::with_capacity(n_entries);
        for e in 0..n_entries {
            let orig = w64[pi][e];
            w64[pi][e] = orig + h;
            let lp = oracle_cls_loss(&cfg, &w64, n_classes, &ids, &labels, batch, seq);
            w64[pi][e] = orig - h;
            let lm = oracle_cls_loss(&cfg, &w64, n_classes, &ids, &labels, batch, seq);
            w64[pi][e] = orig;
            fds.push((lp - lm) / (2.0 * h));
        }
        let fd_scale = fds.iter().map(|f| f.abs()).fold(0f64, f64::max).max(1e-4);
        for (e, &fd) in fds.iter().enumerate() {
            let gv = grads[pi].data()[e] as f64;
            assert!(
                (gv - fd).abs() <= 3e-2 * fd_scale,
                "{name} entry {e}: analytic {gv} vs fd {fd} (scale {fd_scale})"
            );
        }
    }
}

#[test]
fn finetune_loss_and_grads_bit_identical_across_dispatch_levels() {
    let cfg = LmConfig { vocab: 31, n_layers: 2, heads: 2, head_dim: 8, d_ff: 24 };
    let n_classes = 3usize;
    let (batch, seq) = (2usize, 33usize);
    let m = fd_cls_model(&cfg, n_classes, 11400);
    let mut rng = Xoshiro256::new(11500);
    let ids: Vec<i32> =
        (0..batch * seq).map(|_| rng.next_below(cfg.vocab as u64) as i32).collect();
    let labels: Vec<i32> = (0..batch).map(|_| rng.next_below(n_classes as u64) as i32).collect();
    let pool = Pool::serial();
    let run =
        |d: Dispatch| cls_loss_and_grads(&m, d, &ids, &labels, batch, seq, 12, 11600, &pool);
    let (loss_b, grads_b) = run(Dispatch::Scalar);
    for d in [Dispatch::Sse2, Dispatch::Avx2] {
        if !d.available() {
            continue;
        }
        let (loss, grads) = run(d);
        assert_eq!(loss.to_bits(), loss_b.to_bits(), "{}: fine-tune loss", d.name());
        for (pi, (g, gb)) in grads.iter().zip(&grads_b).enumerate() {
            let bits = |m: &Mat| m.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(g), bits(gb), "{}: grad of param {pi} (head is last)", d.name());
        }
    }
}

#[test]
fn finetuning_trajectories_bit_identical_across_thread_counts() {
    let cfg = LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 };
    let task = find_task("SST2").unwrap();
    let (batch, seq) = (2usize, 24usize);
    let run = |pool: &Pool| {
        let mut t =
            FtTrainer::new(cfg.clone(), task.clone(), batch, seq, 8, NativeOpt::adam(2e-3), 17);
        let corpus = TaskCorpus::synthetic(task.clone(), cfg.vocab, seq, 16, 17);
        let mut stream = LabeledStream::new(corpus, batch, 17);
        let losses: Vec<u32> = (0..3)
            .map(|_| t.train_step(&stream.next_batch(), pool, None).unwrap().to_bits())
            .collect();
        (losses, t.model.params)
    };
    let base = run(&Pool::serial());
    for threads in [2usize, 4] {
        let got = run(&Pool::new(threads).with_min_chunk(1));
        assert_eq!(got.0, base.0, "fine-tune loss trajectory t={threads}");
        for (pi, (p, pb)) in got.1.iter().zip(&base.1).enumerate() {
            assert_eq!(p, pb, "param {pi} t={threads} (head is last)");
        }
    }
}

#[test]
fn resumed_finetuning_matches_an_uninterrupted_run_step_for_step() {
    let dir = std::env::temp_dir().join(format!("pamm_prop_ft_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 };
    let task = find_task("MNLI").unwrap(); // 3 classes — head is non-trivial
    let (batch, seq, seed) = (2usize, 16usize, 29u64);
    let pool = Pool::serial();
    let total = 6usize;
    let split = 3usize;
    let mk_stream = || {
        LabeledStream::new(TaskCorpus::synthetic(task.clone(), cfg.vocab, seq, 10, seed), batch, seed)
    };
    let mk_trainer =
        || FtTrainer::new(cfg.clone(), task.clone(), batch, seq, 6, NativeOpt::adam(2e-3), seed);
    let dev = TaskCorpus::synthetic(task.clone(), cfg.vocab, seq, 8, seed ^ 5);

    // Uninterrupted run A.
    let mut a = mk_trainer();
    let mut st_a = mk_stream();
    let losses_a: Vec<u32> = (0..total)
        .map(|_| a.train_step(&st_a.next_batch(), &pool, None).unwrap().to_bits())
        .collect();

    // Run B: train to the split, checkpoint, resume into a FRESH
    // trainer, fast-forward the labeled stream, continue.
    let mut b1 = mk_trainer();
    let mut st_b = mk_stream();
    let mut losses_b: Vec<u32> = (0..split)
        .map(|_| b1.train_step(&st_b.next_batch(), &pool, None).unwrap().to_bits())
        .collect();
    b1.save_checkpoint(&dir, "resume").unwrap();
    drop(b1);

    let mut b2 = mk_trainer();
    b2.resume(&dir, "resume").unwrap();
    assert_eq!(b2.step_no(), split);
    let mut st_b2 = mk_stream();
    st_b2.skip_batches(split);
    losses_b.extend(
        (split..total).map(|_| b2.train_step(&st_b2.next_batch(), &pool, None).unwrap().to_bits()),
    );

    assert_eq!(losses_a, losses_b, "resumed fine-tuning must replay the loss trajectory bitwise");
    for (pi, (pa, pb)) in a.model.params.iter().zip(&b2.model.params).enumerate() {
        assert_eq!(pa, pb, "param {pi}: resumed weights must match (head is last)");
    }
    // Dev evaluation is a pure function of (params, corpus, seed): the
    // two runs must agree on every prediction, hence the exact hits.
    let ea = a.evaluate(&dev, &pool);
    let eb = b2.evaluate(&dev, &pool);
    assert_eq!(ea.hits, eb.hits, "dev hits must match after resume");
    assert_eq!(ea.score.to_bits(), eb.score.to_bits(), "dev metric must match bitwise");
    let _ = std::fs::remove_dir_all(&dir);
}
