//! Property tests for the continuous-batching serve loop
//! (`coordinator::serve`, DESIGN.md §8):
//!
//! * **Worker-count determinism** — a fixed arrival script yields
//!   bit-identical per-request token streams (and identical schedules)
//!   at 1/2/4 serve workers, and every stream equals a standalone
//!   `generate::Decoder` run with the session's derived seed.
//! * **Admission policy** — strict `(arrival, id)` FIFO: admission
//!   steps follow the script order, nothing starves, every request
//!   completes with exactly `max_new` tokens, and the per-completion
//!   cache accounting matches the analytic `kv_cache_bytes` inventory.
//! * **Percentiles** — `benchx::percentile` (shared by the serve table
//!   and the bench reports) matches hand-computed nearest-rank values.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both).

use std::time::Duration;

use pamm::benchx;
use pamm::coordinator::{scripted_load, serve, ServeConfig, ServeRequest};
use pamm::generate::{self, Decoder, GenConfig};
use pamm::model::{LmConfig, TransformerLM};
use pamm::pamm::Eps;
use pamm::poolx::Pool;

fn serve_model() -> TransformerLM {
    TransformerLM::new(
        LmConfig { vocab: 53, n_layers: 2, heads: 2, head_dim: 8, d_ff: 24 },
        41,
    )
}

fn serve_cfg() -> ServeConfig {
    ServeConfig::new(3, 4, Eps::Inf, 2718)
}

/// The per-session seed derivation `serve` uses (documented contract:
/// a session's stream is a pure function of `(seed, prompt)`).
fn session_seed(base: u64, id: usize) -> u64 {
    base ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[test]
fn token_streams_bit_identical_at_one_two_four_workers() {
    let model = serve_model();
    let cfg = serve_cfg();
    let reqs = scripted_load(9, model.cfg.vocab, 7);
    let base = serve(&model, &cfg, &reqs, &Pool::serial()).unwrap();
    assert_eq!(base.completions.len(), reqs.len());
    let schedule = |o: &pamm::coordinator::ServeOutcome| {
        o.completions
            .iter()
            .map(|c| (c.id, c.admitted_step, c.finished_step, c.tokens.clone()))
            .collect::<Vec<_>>()
    };
    let want = schedule(&base);
    for workers in [2usize, 4] {
        let pool = Pool::new(workers).with_min_chunk(1);
        let out = serve(&model, &cfg, &reqs, &pool).unwrap();
        assert_eq!(schedule(&out), want, "schedule/stream drift at {workers} workers");
        assert_eq!(out.steps, base.steps, "step count drift at {workers} workers");
    }
}

#[test]
fn every_stream_equals_a_standalone_decoder() {
    let model = serve_model();
    let cfg = serve_cfg();
    let reqs = scripted_load(6, model.cfg.vocab, 19);
    let out = serve(&model, &cfg, &reqs, &Pool::new(2).with_min_chunk(1)).unwrap();
    let pool = Pool::serial();
    for c in &out.completions {
        let r = reqs.iter().find(|r| r.id == c.id).unwrap();
        let gc = GenConfig::new(
            cfg.k,
            cfg.eps,
            session_seed(cfg.seed, r.id),
            r.prompt.len() + r.max_new,
        );
        let mut dec = Decoder::new(&model, gc);
        dec.prefill(&r.prompt, &pool);
        let toks = dec.generate(r.max_new, &pool);
        assert_eq!(toks, c.tokens, "request {} diverged from a standalone decode", c.id);
        // And the standalone stream itself is prefill/decode parity-clean.
        let got = dec.last_logits().to_vec();
        generate::check_decode_parity(&model, &gc, &r.prompt, &toks, &got, &pool).unwrap();
    }
}

#[test]
fn admission_is_fifo_nothing_starves_and_cache_accounting_is_exact() {
    let model = serve_model();
    // Deliberately adversarial script: ids descending, arrivals
    // staggered so later-arriving low ids must NOT jump the queue.
    let reqs: Vec<ServeRequest> = vec![
        ServeRequest { id: 5, arrival: 0, prompt: vec![1, 2, 3, 4], max_new: 5 },
        ServeRequest { id: 4, arrival: 0, prompt: vec![9, 8, 7], max_new: 4 },
        ServeRequest { id: 3, arrival: 1, prompt: vec![5, 5], max_new: 6 },
        ServeRequest { id: 2, arrival: 3, prompt: vec![6, 1, 2, 3, 4, 5], max_new: 3 },
        ServeRequest { id: 1, arrival: 7, prompt: vec![2, 4], max_new: 4 },
    ];
    let cfg = ServeConfig::new(2, 3, Eps::Inf, 99);
    let out = serve(&model, &cfg, &reqs, &Pool::serial()).unwrap();

    // Nothing starves: every scripted request completes, with exactly
    // max_new tokens in vocab range.
    assert_eq!(out.completions.len(), reqs.len());
    for r in &reqs {
        let c = out.completions.iter().find(|c| c.id == r.id).unwrap();
        assert_eq!(c.tokens.len(), r.max_new, "request {} truncated", r.id);
        assert!(c.tokens.iter().all(|&t| (t as usize) < model.cfg.vocab));
        assert_eq!(c.prompt_len, r.prompt.len());
        assert!(c.admitted_step >= r.arrival, "request {} admitted before arrival", r.id);
        assert!(c.finished_step >= c.admitted_step);
        // Cache accounting: the session's measured peak is exactly the
        // analytic inventory at its (clamped) k and capacity, and the
        // reported savings are dense-minus-bound.
        let k_eff = cfg.k.clamp(1, r.prompt.len());
        let cap = r.prompt.len() + r.max_new;
        let bound = generate::kv_cache_bytes(&model.cfg, k_eff, cap);
        let dense = generate::dense_kv_cache_bytes(&model.cfg, cap);
        assert_eq!(c.cache_peak_bytes, bound, "request {} cache peak", r.id);
        assert_eq!(c.cache_saved_bytes, dense - bound, "request {} cache savings", r.id);
    }
    assert!(out.total_cache_saved_bytes() > 0);
    assert_eq!(out.total_tokens(), reqs.iter().map(|r| r.max_new).sum::<usize>());

    // FIFO: admission steps are monotone in (arrival, id) script order.
    let mut script: Vec<&ServeRequest> = reqs.iter().collect();
    script.sort_by_key(|r| (r.arrival, r.id));
    let admits: Vec<usize> = script
        .iter()
        .map(|r| out.completions.iter().find(|c| c.id == r.id).unwrap().admitted_step)
        .collect();
    assert!(
        admits.windows(2).all(|w| w[0] <= w[1]),
        "admission steps {admits:?} violate (arrival, id) FIFO order"
    );
}

#[test]
fn percentile_matches_hand_computed_nearest_rank() {
    let ms = |v: u64| Duration::from_millis(v);
    // Ten sorted samples: nearest rank round((n-1)·p).
    let ten: Vec<Duration> = (1..=10).map(ms).collect();
    assert_eq!(benchx::percentile(&ten, 0.0), ms(1));
    assert_eq!(benchx::percentile(&ten, 0.5), ms(6)); // round(4.5) = 5
    assert_eq!(benchx::percentile(&ten, 0.95), ms(10)); // round(8.55) = 9
    assert_eq!(benchx::percentile(&ten, 1.0), ms(10));
    // Odd length: p50 is the exact median.
    let five: Vec<Duration> = [3, 7, 9, 20, 31].iter().map(|&v| ms(v)).collect();
    assert_eq!(benchx::percentile(&five, 0.5), ms(9));
    assert_eq!(benchx::percentile(&five, 0.99), ms(31));
    // Single sample: every percentile is that sample.
    assert_eq!(benchx::percentile(&[ms(4)], 0.5), ms(4));
}
