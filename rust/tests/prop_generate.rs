//! Property tests for native generation with the PAMM-compressed KV
//! cache (`generate::Decoder`, DESIGN.md §8):
//!
//! * **Fidelity oracle** — at all-generators (k = prompt length,
//!   `Eps::Inf`) the compressed cache is the identity up to Lemma-1 α
//!   rounding, so prefill logits must match an independent f64 dense
//!   implementation of the whole forward within a small relative
//!   tolerance.
//! * **Decode parity** — incremental decode is *bitwise* identical to
//!   a one-shot prefill of `prompt ++ generated` whose generator
//!   domain is the prompt, across k (including the clamp), ε-drop
//!   settings, and prompt/continuation lengths.
//! * **Thread parity** — the whole prefill + greedy-decode trajectory
//!   (token stream and final logits) is bit-identical at 1/2/4 pool
//!   threads.
//! * **Dispatch parity** — the two decode-side kernels this subsystem
//!   adds, `IncrementalCompressor::fold_on` and
//!   `attention::attend_cached_on`, are bit-identical at
//!   scalar/sse2/avx2 (explicit `Dispatch` arguments; no process-wide
//!   `kernels::force`).
//! * **Memory** — the measured cache peak equals the analytic
//!   `kv_cache_bytes` bound exactly, decode allocates nothing, and the
//!   bound undercuts the dense `2·T·d_model` baseline.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both).

use pamm::attention;
use pamm::autograd::LN_EPS;
use pamm::generate::{self, check_decode_parity, Decoder, GenConfig};
use pamm::model::{self, LmConfig, TransformerLM};
use pamm::pamm::{compress_with, sample_generators, Eps, IncrementalCompressor};
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::Dispatch;
use pamm::tensor::Mat;

fn rand_mat(rows: usize, cols: usize, std: f32, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    Mat::random_normal(rows, cols, std, &mut rng)
}

fn token_ids(vocab: usize, n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_below(vocab as u64) as i32).collect()
}

/// A model with weights large enough that logits are well above the
/// f32 noise floor (the 0.02 production init would make the relative
/// oracle comparison vacuous), small enough not to blow up through the
/// stacked blocks.
fn oracle_model(cfg: &LmConfig, seed: u64) -> TransformerLM {
    let mut m = TransformerLM::new(cfg.clone(), seed);
    let dm = cfg.d_model();
    let mut s = seed;
    let mut next = |rows: usize, cols: usize, std: f32| {
        s += 1;
        rand_mat(rows, cols, std, s)
    };
    m.params[0] = next(cfg.vocab, dm, 0.4); // emb (tied head)
    for b in 0..cfg.n_layers {
        let p = 1 + b * model::PARAMS_PER_BLOCK;
        let mut g = next(1, dm, 0.2);
        for v in g.data_mut() {
            *v += 1.0;
        }
        m.params[p] = g;
        m.params[p + 1] = next(1, dm, 0.1);
        m.params[p + 2] = next(dm, dm, 0.3);
        m.params[p + 3] = next(dm, dm, 0.3);
        m.params[p + 4] = next(dm, dm, 0.3);
        let mut g2 = next(1, dm, 0.2);
        for v in g2.data_mut() {
            *v += 1.0;
        }
        m.params[p + 5] = g2;
        m.params[p + 6] = next(1, dm, 0.1);
        m.params[p + 7] = next(dm, cfg.d_ff, 0.3);
        m.params[p + 8] = next(cfg.d_ff, dm, 0.3);
    }
    let lnf = 1 + cfg.n_layers * model::PARAMS_PER_BLOCK;
    let mut gf = next(1, dm, 0.2);
    for v in gf.data_mut() {
        *v += 1.0;
    }
    m.params[lnf] = gf;
    m.params[lnf + 1] = next(1, dm, 0.1);
    m
}

// ---------------------------------------------------------------------------
// f64 oracle — an independent dense implementation of the inference
// forward (same structure as prop_model's training oracle, single
// sequence, last-row tied-head logits instead of the loss).
// ---------------------------------------------------------------------------

fn mm64(a: &[f64], b: &[f64], r: usize, k: usize, c: usize) -> Vec<f64> {
    let mut out = vec![0f64; r * c];
    for i in 0..r {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..c {
                out[i * c + j] += av * b[p * c + j];
            }
        }
    }
    out
}

fn ln64(x: &[f64], rows: usize, n: usize, g: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0f64; rows * n];
    for i in 0..rows {
        let xr = &x[i * n..(i + 1) * n];
        let mu: f64 = xr.iter().sum::<f64>() / n as f64;
        let var: f64 = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / n as f64;
        let r = 1.0 / (var + LN_EPS as f64).sqrt();
        for j in 0..n {
            out[i * n + j] = (xr[j] - mu) * r * g[j] + b[j];
        }
    }
    out
}

fn gelu64(z: f64) -> f64 {
    let c = 0.7978845608028654f64; // √(2/π)
    let a = 0.044715f64;
    0.5 * z * (1.0 + (c * (z + a * z * z * z)).tanh())
}

/// Dense causal multi-head attention over one sequence, token-major.
fn attn64(qp: &[f64], kp: &[f64], vp: &[f64], seq: usize, heads: usize, dh: usize) -> Vec<f64> {
    let dm = heads * dh;
    let scale = 1.0 / (dh as f64).sqrt();
    let mut out = vec![0f64; seq * dm];
    for h in 0..heads {
        for i in 0..seq {
            let ri = i * dm + h * dh;
            let mut scores = vec![0f64; i + 1];
            for (j, s) in scores.iter_mut().enumerate() {
                let rj = j * dm + h * dh;
                let mut acc = 0f64;
                for c in 0..dh {
                    acc += qp[ri + c] * kp[rj + c];
                }
                *s = scale * acc;
            }
            let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0f64;
            for s in scores.iter_mut() {
                *s = (*s - mx).exp();
                sum += *s;
            }
            for c in 0..dh {
                let mut acc = 0f64;
                for (j, p) in scores.iter().enumerate() {
                    let rj = j * dm + h * dh;
                    acc += p * vp[rj + c];
                }
                out[ri + c] = acc / sum;
            }
        }
    }
    out
}

/// The whole inference forward in f64, dense K/V (the function the
/// compressed-cache forward equals at all-generators): returns the
/// last position's tied-head logits.
fn oracle_logits(cfg: &LmConfig, params: &[Vec<f64>], ids: &[i32]) -> Vec<f64> {
    let dm = cfg.d_model();
    let seq = ids.len();
    let emb = &params[0];
    let mut x = vec![0f64; seq * dm];
    for (i, &id) in ids.iter().enumerate() {
        x[i * dm..(i + 1) * dm].copy_from_slice(&emb[id as usize * dm..(id as usize + 1) * dm]);
    }
    for b in 0..cfg.n_layers {
        let p = 1 + b * model::PARAMS_PER_BLOCK;
        let h1 = ln64(&x, seq, dm, &params[p], &params[p + 1]);
        let qp = mm64(&h1, &params[p + 2], seq, dm, dm);
        let kp = mm64(&h1, &params[p + 3], seq, dm, dm);
        let vp = mm64(&h1, &params[p + 4], seq, dm, dm);
        let attn = attn64(&qp, &kp, &vp, seq, cfg.heads, cfg.head_dim);
        for (xv, av) in x.iter_mut().zip(&attn) {
            *xv += av;
        }
        let h2 = ln64(&x, seq, dm, &params[p + 5], &params[p + 6]);
        let mut z = mm64(&h2, &params[p + 7], seq, dm, cfg.d_ff);
        for v in z.iter_mut() {
            *v = gelu64(*v);
        }
        let y = mm64(&z, &params[p + 8], seq, cfg.d_ff, dm);
        for (xv, yv) in x.iter_mut().zip(&y) {
            *xv += yv;
        }
    }
    let lnf = 1 + cfg.n_layers * model::PARAMS_PER_BLOCK;
    let hf = ln64(&x, seq, dm, &params[lnf], &params[lnf + 1]);
    let hr = &hf[(seq - 1) * dm..seq * dm];
    (0..cfg.vocab)
        .map(|t| {
            let er = &emb[t * dm..(t + 1) * dm];
            hr.iter().zip(er).map(|(a, b)| a * b).sum()
        })
        .collect()
}

fn params64(m: &TransformerLM) -> Vec<Vec<f64>> {
    m.params.iter().map(|p| p.data().iter().map(|&v| v as f64).collect()).collect()
}

#[test]
fn all_generators_prefill_matches_the_f64_dense_oracle() {
    let cfg = LmConfig { vocab: 23, n_layers: 2, heads: 2, head_dim: 4, d_ff: 12 };
    let m = oracle_model(&cfg, 4100);
    let prompt = token_ids(cfg.vocab, 10, 4200);
    let pool = Pool::serial();
    // k = prompt length, Eps::Inf: every row a generator, α ≈ 1 up to
    // Lemma-1 rounding — the cache is semantically the dense one.
    let gcfg = GenConfig::new(prompt.len(), Eps::Inf, 5, prompt.len());
    let mut dec = Decoder::new(&m, gcfg);
    let got = dec.prefill(&prompt, &pool).to_vec();
    assert_eq!(dec.effective_k(), prompt.len());
    let want = oracle_logits(&cfg, &params64(&m), &prompt);
    let scale = want.iter().fold(1f64, |a, w| a.max(w.abs()));
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            ((*g as f64) - w).abs() <= 2e-3 * scale,
            "logit {i}: native {g} vs oracle {w} (scale {scale})"
        );
    }
}

#[test]
fn incremental_decode_matches_one_shot_prefill_bitwise_across_shapes() {
    let cfg = LmConfig { vocab: 41, n_layers: 3, heads: 2, head_dim: 8, d_ff: 32 };
    let model = TransformerLM::new(cfg.clone(), 77);
    let pool = Pool::new(2).with_min_chunk(1);
    for (k, eps, plen, n_new) in [
        (1usize, Eps::Inf, 5usize, 6usize), // degenerate single generator
        (4, Eps::Inf, 12, 8),
        (8, Eps::Val(0.6), 9, 7), // ε-drop path active at decode folds
        (16, Eps::Inf, 7, 4),     // k clamps to the generator domain
    ] {
        let prompt = token_ids(cfg.vocab, plen, 5000 + k as u64);
        let gcfg = GenConfig::new(k, eps, 13, plen + n_new);
        let mut dec = Decoder::new(&model, gcfg);
        dec.prefill(&prompt, &pool);
        let toks = dec.generate(n_new, &pool);
        assert_eq!(toks.len(), n_new);
        assert_eq!(dec.len(), plen + n_new);
        let got = dec.last_logits().to_vec();
        check_decode_parity(&model, &gcfg, &prompt, &toks, &got, &pool)
            .unwrap_or_else(|e| panic!("k={k} eps={eps:?} plen={plen}: {e}"));
    }
}

#[test]
fn generation_is_bit_identical_at_any_thread_count() {
    let cfg = LmConfig { vocab: 101, n_layers: 2, heads: 2, head_dim: 16, d_ff: 64 };
    let model = TransformerLM::new(cfg.clone(), 99);
    let prompt = token_ids(cfg.vocab, 16, 6000);
    let run = |pool: &Pool| {
        let mut dec = Decoder::new(&model, GenConfig::new(6, Eps::Inf, 11, 48));
        dec.prefill(&prompt, pool);
        let toks = dec.generate(12, pool);
        let bits: Vec<u32> = dec.last_logits().iter().map(|v| v.to_bits()).collect();
        (toks, bits)
    };
    let base = run(&Pool::serial());
    for threads in [2usize, 4] {
        assert_eq!(run(&Pool::new(threads).with_min_chunk(1)), base, "threads {threads}");
    }
}

#[test]
fn fold_and_cached_attention_bit_identical_across_dispatch_levels() {
    // The two kernels the generation subsystem adds, driven directly
    // through their explicit-Dispatch entry points (prop_kernels
    // already covers the shared GEMM ladder).
    let (n, dm, heads, dh, k, q_rows) = (24usize, 16usize, 2usize, 8usize, 6usize, 4usize);
    let h = rand_mat(n + q_rows, dm, 0.8, 4400); // prefix rows + decode rows
    let wk = rand_mat(dm, dm, 0.3, 4401);
    let wv = rand_mat(dm, dm, 0.3, 4402);
    let q = rand_mat(q_rows, dm, 0.5, 4403);
    let pool = Pool::serial();
    let prefix = Mat::from_vec(n, dm, h.data()[..n * dm].to_vec());
    let run = |d: Dispatch| {
        let mut rng = Xoshiro256::new(4500);
        let gen_idx = sample_generators(&mut rng, n, k);
        let mut comp = compress_with(&prefix, &gen_idx, Eps::Inf, &pool);
        let mut inc = IncrementalCompressor::new(&comp);
        for r in n..n + q_rows {
            // ε tight enough that some folds take the drop path too.
            inc.fold_on(d, &mut comp, h.row(r), Eps::Val(0.5));
        }
        let gk = comp.project_generators(&wk);
        let gv = comp.project_generators(&wv);
        let out = attention::attend_cached_on(
            d,
            &q,
            n,
            &gk,
            &gv,
            &comp.alpha,
            &comp.assign,
            heads,
            dh,
            &pool,
        );
        let alpha_bits: Vec<u32> = comp.alpha.iter().map(|v| v.to_bits()).collect();
        let out_bits: Vec<u32> = out.data().iter().map(|v| v.to_bits()).collect();
        (comp.assign.clone(), alpha_bits, out_bits)
    };
    let base = run(Dispatch::Scalar);
    for d in [Dispatch::Sse2, Dispatch::Avx2] {
        if !d.available() {
            continue;
        }
        assert_eq!(run(d), base, "{} vs scalar", d.name());
    }
}

#[test]
fn measured_cache_peak_equals_the_analytic_bound_and_undercuts_dense() {
    let cfg = LmConfig { vocab: 127, n_layers: 3, heads: 2, head_dim: 16, d_ff: 64 };
    let model = TransformerLM::new(cfg.clone(), 31);
    let pool = Pool::serial();
    let (plen, n_new, k) = (24usize, 16usize, 6usize);
    let gcfg = GenConfig::new(k, Eps::Inf, 3, plen + n_new);
    let mut dec = Decoder::new(&model, gcfg);
    dec.prefill(&token_ids(cfg.vocab, plen, 4600), &pool);
    let peak_after_prefill = dec.cache_peak_bytes();
    dec.generate(n_new, &pool);
    // Decode must not allocate: α/f were pre-sized to max_tokens.
    assert_eq!(dec.cache_peak_bytes(), peak_after_prefill, "decode allocated cache memory");
    let bound = generate::kv_cache_bytes(&cfg, k, plen + n_new);
    assert_eq!(dec.cache_peak_bytes(), bound, "measured peak vs analytic bound");
    assert_eq!(dec.cache_bound_bytes(), bound);
    let dense = generate::dense_kv_cache_bytes(&cfg, plen + n_new);
    assert_eq!(dec.dense_baseline_bytes(), dense);
    assert!(bound < dense, "compressed cache {bound} not below dense {dense}");
}
