//! Property tests for the multi-layer native LM (`model` +
//! `coordinator::LmTrainer` over the multi-op graph tape):
//!
//! * f64 finite-difference gradient check through **two stacked
//!   transformer blocks** (all-generators, so the compressed forward
//!   is the function the oracle differentiates),
//! * scalar==sse2==avx2 bit-equality of loss and every gradient,
//! * 1/2/4-thread parity of whole training trajectories,
//! * the PAMM MLP op at all-generators == the exact dense backward,
//! * measured per-layer backward peak ≤ the model-level analytic
//!   bound, with the tape's saved inventory matching its analytic rows,
//! * checkpoint round-trip + resume: a save/reload/continue run is
//!   bit-identical, step for step, to an uninterrupted one.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both).

use pamm::autograd::{Tape, LN_EPS};
use pamm::coordinator::{LmTrainer, NativeOpt};
use pamm::data::batcher::BatchIterator;
use pamm::memory::MemoryLedger;
use pamm::model::{self, LmConfig, TransformerLM};
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::{self, Dispatch};
use pamm::tensor::Mat;

fn rand_mat(rows: usize, cols: usize, std: f32, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    Mat::random_normal(rows, cols, std, &mut rng)
}

fn token_batch(vocab: usize, n: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Xoshiro256::new(seed);
    let ids = (0..n).map(|_| rng.next_below(vocab as u64) as i32).collect();
    let targets = (0..n).map(|_| rng.next_below(vocab as u64) as i32).collect();
    (ids, targets)
}

/// A two-block test model with weights large enough that every
/// parameter group gets a well-sized gradient (the 0.02 production
/// init leaves deep-layer grads in the f32 noise floor at FD scales).
fn fd_model(cfg: &LmConfig, seed: u64) -> TransformerLM {
    let mut m = TransformerLM::new(cfg.clone(), seed);
    let dm = cfg.d_model();
    let mut s = seed;
    let mut next = |rows: usize, cols: usize, std: f32| {
        s += 1;
        rand_mat(rows, cols, std, s)
    };
    m.params[0] = next(cfg.vocab, dm, 0.5); // emb
    for b in 0..cfg.n_layers {
        let p = 1 + b * model::PARAMS_PER_BLOCK;
        let mut g = next(1, dm, 0.2);
        for v in g.data_mut() {
            *v += 1.0; // gains near 1, not 0
        }
        m.params[p] = g;
        m.params[p + 1] = next(1, dm, 0.1);
        m.params[p + 2] = next(dm, dm, 0.4);
        m.params[p + 3] = next(dm, dm, 0.4);
        m.params[p + 4] = next(dm, dm, 0.4);
        let mut g2 = next(1, dm, 0.2);
        for v in g2.data_mut() {
            *v += 1.0;
        }
        m.params[p + 5] = g2;
        m.params[p + 6] = next(1, dm, 0.1);
        m.params[p + 7] = next(dm, cfg.d_ff, 0.4);
        m.params[p + 8] = next(cfg.d_ff, dm, 0.4);
    }
    let lnf = 1 + cfg.n_layers * model::PARAMS_PER_BLOCK;
    let mut gf = next(1, dm, 0.2);
    for v in gf.data_mut() {
        *v += 1.0;
    }
    m.params[lnf] = gf;
    m.params[lnf + 1] = next(1, dm, 0.1);
    m
}

// ---------------------------------------------------------------------------
// f64 oracle — an independent dense implementation of the whole model
// ---------------------------------------------------------------------------

fn mm64(a: &[f64], b: &[f64], r: usize, k: usize, c: usize) -> Vec<f64> {
    let mut out = vec![0f64; r * c];
    for i in 0..r {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..c {
                out[i * c + j] += av * b[p * c + j];
            }
        }
    }
    out
}

fn ln64(x: &[f64], rows: usize, n: usize, g: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0f64; rows * n];
    for i in 0..rows {
        let xr = &x[i * n..(i + 1) * n];
        let mu: f64 = xr.iter().sum::<f64>() / n as f64;
        let var: f64 = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / n as f64;
        let r = 1.0 / (var + LN_EPS as f64).sqrt();
        for j in 0..n {
            out[i * n + j] = (xr[j] - mu) * r * g[j] + b[j];
        }
    }
    out
}

fn gelu64(z: f64) -> f64 {
    let c = 0.7978845608028654f64; // √(2/π)
    let a = 0.044715f64;
    0.5 * z * (1.0 + (c * (z + a * z * z * z)).tanh())
}

/// Dense causal multi-head attention, token-major in and out.
fn attn64(
    qp: &[f64],
    kp: &[f64],
    vp: &[f64],
    batch: usize,
    seq: usize,
    heads: usize,
    dh: usize,
) -> Vec<f64> {
    let dm = heads * dh;
    let scale = 1.0 / (dh as f64).sqrt();
    let mut out = vec![0f64; batch * seq * dm];
    for b in 0..batch {
        for h in 0..heads {
            for i in 0..seq {
                let ri = (b * seq + i) * dm + h * dh;
                let mut scores = vec![0f64; i + 1];
                for (j, s) in scores.iter_mut().enumerate() {
                    let rj = (b * seq + j) * dm + h * dh;
                    let mut acc = 0f64;
                    for c in 0..dh {
                        acc += qp[ri + c] * kp[rj + c];
                    }
                    *s = scale * acc;
                }
                let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut sum = 0f64;
                for s in scores.iter_mut() {
                    *s = (*s - mx).exp();
                    sum += *s;
                }
                for c in 0..dh {
                    let mut acc = 0f64;
                    for (j, p) in scores.iter().enumerate() {
                        let rj = (b * seq + j) * dm + h * dh;
                        acc += p * vp[rj + c];
                    }
                    out[ri + c] = acc / sum;
                }
            }
        }
    }
    out
}

/// The whole model in f64, dense (no compression — the function the
/// compressed forward equals at all-generators, α = 1, β = 1).
fn oracle_loss(
    cfg: &LmConfig,
    params: &[Vec<f64>],
    ids: &[i32],
    targets: &[i32],
    batch: usize,
    seq: usize,
) -> f64 {
    let dm = cfg.d_model();
    let tokens = batch * seq;
    let emb = &params[0];
    let mut x = vec![0f64; tokens * dm];
    for (i, &id) in ids.iter().enumerate() {
        x[i * dm..(i + 1) * dm].copy_from_slice(&emb[id as usize * dm..(id as usize + 1) * dm]);
    }
    for b in 0..cfg.n_layers {
        let p = 1 + b * model::PARAMS_PER_BLOCK;
        let h1 = ln64(&x, tokens, dm, &params[p], &params[p + 1]);
        let qp = mm64(&h1, &params[p + 2], tokens, dm, dm);
        let kp = mm64(&h1, &params[p + 3], tokens, dm, dm);
        let vp = mm64(&h1, &params[p + 4], tokens, dm, dm);
        let attn = attn64(&qp, &kp, &vp, batch, seq, cfg.heads, cfg.head_dim);
        for (xv, av) in x.iter_mut().zip(&attn) {
            *xv += av;
        }
        let h2 = ln64(&x, tokens, dm, &params[p + 5], &params[p + 6]);
        let mut z = mm64(&h2, &params[p + 7], tokens, dm, cfg.d_ff);
        for v in z.iter_mut() {
            *v = gelu64(*v);
        }
        let y = mm64(&z, &params[p + 8], tokens, cfg.d_ff, dm);
        for (xv, yv) in x.iter_mut().zip(&y) {
            *xv += yv;
        }
    }
    let lnf = 1 + cfg.n_layers * model::PARAMS_PER_BLOCK;
    let hf = ln64(&x, tokens, dm, &params[lnf], &params[lnf + 1]);
    let mut loss = 0f64;
    for i in 0..tokens {
        let hr = &hf[i * dm..(i + 1) * dm];
        let mut logits = vec![0f64; cfg.vocab];
        for (t, l) in logits.iter_mut().enumerate() {
            let er = &emb[t * dm..(t + 1) * dm];
            *l = hr.iter().zip(er).map(|(a, b)| a * b).sum();
        }
        let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = mx + logits.iter().map(|l| (l - mx).exp()).sum::<f64>().ln();
        loss += lse - logits[targets[i] as usize];
    }
    loss / tokens as f64
}

#[test]
fn finite_difference_gradient_check_through_two_stacked_blocks() {
    let cfg = LmConfig { vocab: 17, n_layers: 2, heads: 2, head_dim: 3, d_ff: 10 };
    let (batch, seq) = (1usize, 6usize);
    let tokens = batch * seq;
    let m = fd_model(&cfg, 9000);
    let (ids, targets) = token_batch(cfg.vocab, tokens, 9100);
    let pool = Pool::serial();

    // All generators: the compression is the identity up to Lemma-1 α
    // rounding (≈1e-7), β = 1 — the analytic gradients are exact for
    // the dense function the oracle computes.
    let k = tokens;
    let mut rng = Xoshiro256::new(9200);
    let (loss, grads) = m.loss_and_grads(
        kernels::active(),
        &ids,
        &targets,
        batch,
        seq,
        k,
        Eps::Inf,
        &mut rng,
        &pool,
        None,
    );
    let params64: Vec<Vec<f64>> =
        m.params.iter().map(|p| p.data().iter().map(|&v| v as f64).collect()).collect();
    let oracle = oracle_loss(&cfg, &params64, &ids, &targets, batch, seq);
    assert!(
        (loss as f64 - oracle).abs() < 1e-3 * oracle.abs().max(1.0),
        "forward mismatch: native {loss} vs oracle {oracle}"
    );

    let h = 1e-3f64;
    let mut w64 = params64;
    let names = model::param_names(&cfg);
    for (pi, name) in names.iter().enumerate() {
        let n_entries = w64[pi].len();
        let mut fds = Vec::with_capacity(n_entries);
        for e in 0..n_entries {
            let orig = w64[pi][e];
            w64[pi][e] = orig + h;
            let lp = oracle_loss(&cfg, &w64, &ids, &targets, batch, seq);
            w64[pi][e] = orig - h;
            let lm = oracle_loss(&cfg, &w64, &ids, &targets, batch, seq);
            w64[pi][e] = orig;
            fds.push((lp - lm) / (2.0 * h));
        }
        let fd_scale = fds.iter().map(|f| f.abs()).fold(0f64, f64::max).max(1e-4);
        for (e, &fd) in fds.iter().enumerate() {
            let gv = grads[pi].data()[e] as f64;
            assert!(
                (gv - fd).abs() <= 3e-2 * fd_scale,
                "{name} entry {e}: analytic {gv} vs fd {fd} (scale {fd_scale})"
            );
        }
    }
}

#[test]
fn loss_and_grads_bit_identical_across_dispatch_levels() {
    let cfg = LmConfig { vocab: 31, n_layers: 2, heads: 2, head_dim: 8, d_ff: 24 };
    let (batch, seq) = (2usize, 33usize);
    let m = fd_model(&cfg, 9400);
    let (ids, targets) = token_batch(cfg.vocab, batch * seq, 9500);
    let pool = Pool::serial();
    let run = |d: Dispatch| {
        let mut rng = Xoshiro256::new(9600);
        m.loss_and_grads(d, &ids, &targets, batch, seq, 12, Eps::Inf, &mut rng, &pool, None)
    };
    let (loss_b, grads_b) = run(Dispatch::Scalar);
    for d in [Dispatch::Sse2, Dispatch::Avx2] {
        if !d.available() {
            continue;
        }
        let (loss, grads) = run(d);
        assert_eq!(loss.to_bits(), loss_b.to_bits(), "{}: loss", d.name());
        for (pi, (g, gb)) in grads.iter().zip(&grads_b).enumerate() {
            let bits = |m: &Mat| m.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(g), bits(gb), "{}: grad of param {pi}", d.name());
        }
    }
}

#[test]
fn training_trajectories_bit_identical_across_thread_counts() {
    let cfg = LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 };
    let (batch, seq) = (2usize, 24usize);
    let run = |pool: &Pool| {
        let mut t = LmTrainer::new(cfg.clone(), batch, seq, 8, NativeOpt::adam(2e-3), 17);
        let mut it = BatchIterator::from_seed(cfg.vocab, batch, seq, 17);
        let losses: Vec<u32> =
            (0..3)
            .map(|_| t.train_step(&it.next_batch().tokens, pool, None).unwrap().to_bits())
            .collect();
        (losses, t.model.params)
    };
    let base = run(&Pool::serial());
    for threads in [2usize, 4] {
        let got = run(&Pool::new(threads).with_min_chunk(1));
        assert_eq!(got.0, base.0, "loss trajectory t={threads}");
        for (pi, (p, pb)) in got.1.iter().zip(&base.1).enumerate() {
            assert_eq!(p, pb, "param {pi} t={threads}");
        }
    }
}

#[test]
fn mlp_all_generators_matches_the_exact_dense_backward() {
    // Every row a generator ⇒ Ã = X (α = 1 up to Lemma-1 rounding),
    // β = 1: the PAMM MLP op must reproduce the dense MLP backward
    // z = X·W₁, h = GELU(z), dW₂ = hᵀdY, dz = dY·W₂ᵀ ∘ GELU'(z),
    // dW₁ = Xᵀdz, dX = dz·W₁ᵀ.
    let (b, dm, dff) = (40usize, 10usize, 14usize);
    let x = rand_mat(b, dm, 1.0, 9700);
    let w1 = rand_mat(dm, dff, 0.3, 9701);
    let w2 = rand_mat(dff, dm, 0.3, 9702);
    let dy = rand_mat(b, dm, 1.0, 9703);
    let idx: Vec<usize> = (0..b).collect();
    let pool = Pool::serial();

    let mut tape = Tape::new();
    let xid = tape.leaf();
    let (y, yid) = tape.mlp_pamm(&x, xid, &w1, 0, &w2, 1, &idx, Eps::Inf, &pool, None);
    tape.seed(yid, dy.clone());
    let res = tape.backward(kernels::active(), &[w1.clone(), w2.clone()], &pool, None);

    // Dense reference in plain f32 Mat ops.
    let z = x.matmul(&w1);
    let mut hh = z.clone();
    for v in hh.data_mut() {
        *v = pamm::autograd::gelu(*v);
    }
    let y_ref = hh.matmul(&w2);
    let mut dz = dy.matmul(&w2.transpose());
    for (dv, &zv) in dz.data_mut().iter_mut().zip(z.data()) {
        *dv *= pamm::autograd::gelu_grad(zv);
    }
    let dw1_ref = x.t_matmul(&dz);
    let dw2_ref = hh.t_matmul(&dy);
    let dx_ref = dz.matmul(&w1.transpose());

    let close = |got: &Mat, want: &Mat, name: &str| {
        let scale = want.frob_norm().max(1e-6);
        assert!(
            got.max_abs_diff(want) <= 1e-3 * scale,
            "{name}: diff {} vs scale {scale}",
            got.max_abs_diff(want)
        );
    };
    close(&y, &y_ref, "forward y");
    close(&res.params[0], &dw1_ref, "dw1");
    close(&res.params[1], &dw2_ref, "dw2");
    close(res.values[xid].as_ref().unwrap(), &dx_ref, "dx");
}

#[test]
fn measured_model_backward_peak_respects_the_model_level_bound() {
    let cfg = LmConfig { vocab: 128, n_layers: 2, heads: 2, head_dim: 16, d_ff: 64 };
    let (batch, seq) = (1usize, 64usize);
    let k = 8usize;
    let (toks, _) = token_batch(cfg.vocab, batch * (seq + 1), 9800);
    let threads = 2usize;
    let ledger = MemoryLedger::new();
    let mut report = None;
    std::thread::scope(|sc| {
        sc.spawn(|| {
            let cold = Pool::new(threads).with_min_chunk(1);
            let mut t = LmTrainer::new(cfg.clone(), batch, seq, k, NativeOpt::adam(1e-3), 23);
            report = Some(t.step_report(kernels::active(), &toks, &cold, Some(&ledger)));
        });
    });
    let rep = report.unwrap().unwrap();
    assert_eq!(ledger.saved(), rep.saved_bytes, "ledger records the tape inventory exactly");
    let shape = pamm::attention::AttnShape::new(batch, cfg.heads, seq, cfg.head_dim, true);
    // The shared tail matches its analytic inventory to the byte, and
    // every block undercuts the dense baseline.
    assert_eq!(
        rep.inventory.embedding + rep.inventory.tail,
        model::tail_saved_bytes(&cfg, &shape)
    );
    let dense_block = model::dense_block_saved_bytes(&cfg, &shape);
    for (i, &b) in rep.inventory.blocks.iter().enumerate() {
        assert!(b < dense_block, "block {i}: saved {b} vs dense {dense_block}");
    }
    assert!(rep.saved_bytes < model::dense_model_saved_bytes(&cfg, &shape));
    // Both phase trackers saw real transients, and the backward peak
    // sits under the model-level analytic bound.
    assert!(ledger.forward.peak() > 0);
    assert!(ledger.backward.peak() > 0);
    let bound = model::backward_peak_bound(&cfg, &shape, k, threads);
    assert!(
        ledger.backward.peak() <= bound,
        "measured backward peak {} exceeds the model bound {bound}",
        ledger.backward.peak()
    );
}

#[test]
fn resumed_training_matches_an_uninterrupted_run_step_for_step() {
    let dir = std::env::temp_dir().join(format!("pamm_prop_model_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 };
    let (batch, seq, seed) = (2usize, 16usize, 29u64);
    let pool = Pool::serial();
    let total = 6usize;
    let split = 3usize;

    // Uninterrupted run A.
    let mut a = LmTrainer::new(cfg.clone(), batch, seq, 6, NativeOpt::adam(2e-3), seed);
    let mut it_a = BatchIterator::from_seed(cfg.vocab, batch, seq, seed);
    let losses_a: Vec<u32> =
        (0..total)
        .map(|_| a.train_step(&it_a.next_batch().tokens, &pool, None).unwrap().to_bits())
        .collect();

    // Run B: train to the split, checkpoint, resume into a FRESH
    // trainer, fast-forward the stream, continue.
    let mut b1 = LmTrainer::new(cfg.clone(), batch, seq, 6, NativeOpt::adam(2e-3), seed);
    let mut it_b = BatchIterator::from_seed(cfg.vocab, batch, seq, seed);
    let mut losses_b: Vec<u32> = (0..split)
        .map(|_| b1.train_step(&it_b.next_batch().tokens, &pool, None).unwrap().to_bits())
        .collect();
    b1.save_checkpoint(&dir, "resume").unwrap();
    drop(b1);

    let mut b2 = LmTrainer::new(cfg.clone(), batch, seq, 6, NativeOpt::adam(2e-3), seed);
    b2.resume(&dir, "resume").unwrap();
    assert_eq!(b2.step_no(), split);
    let mut it_b2 = BatchIterator::from_seed(cfg.vocab, batch, seq, seed);
    it_b2.skip_batches(split);
    losses_b.extend(
        (split..total)
            .map(|_| b2.train_step(&it_b2.next_batch().tokens, &pool, None).unwrap().to_bits()),
    );

    assert_eq!(losses_a, losses_b, "resumed run must replay the loss trajectory bitwise");
    for (pi, (pa, pb)) in a.model.params.iter().zip(&b2.model.params).enumerate() {
        assert_eq!(pa, pb, "param {pi}: resumed weights must match the uninterrupted run");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
