//! Integration tests over the real runtime: artifacts → PJRT → coordinator.
//!
//! These require `make artifacts` (at least the quick preset) and
//! **skip with a note when the artifact set is absent** (e.g. in the
//! Rust-only CI job), so `cargo test -q` stays green either way. They
//! pin down: manifest↔zoo agreement, kernel three-way agreement,
//! training convergence through the full stack, eval, checkpoints, DDP
//! equivalence and determinism.
//!
//! The whole suite is gated on the `pjrt` cargo feature — the default
//! build has no PJRT engine to run them against.

#![cfg(feature = "pjrt")]

use pamm::checkpoint;
use pamm::config::{RunConfig, Variant};
use pamm::coordinator::ddp::DdpTrainer;
use pamm::coordinator::session::TrainSession;
use pamm::coordinator::train_run;
use pamm::data::batcher::BatchIterator;
use pamm::memory::ModelGeometry;
use pamm::runtime::Engine;

fn artifacts_dir() -> String {
    std::env::var("PAMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Load the artifact set, or None (test skips) when it hasn't been
/// built — the Rust-only CI job has no `make artifacts` step. Set
/// `PAMM_REQUIRE_ARTIFACTS=1` in artifact-equipped CI so a broken
/// loader fails loudly instead of skip-passing the whole suite.
fn try_engine() -> Option<Engine> {
    match Engine::load(artifacts_dir()) {
        Ok(engine) => Some(engine),
        Err(e) => {
            if std::env::var("PAMM_REQUIRE_ARTIFACTS").is_ok() {
                panic!("artifacts required (PAMM_REQUIRE_ARTIFACTS) but unavailable: {e:#}");
            }
            eprintln!("skipping e2e test: {e:#} — run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_param_counts_match_native_zoo() {
    let Some(engine) = try_engine() else { return };
    for c in &engine.manifest.configs {
        if let Some(g) = ModelGeometry::by_name(&c.name) {
            assert_eq!(
                g.param_count(),
                c.param_count,
                "param_count drift for {} (python vs rust analytic model)",
                c.name
            );
            assert_eq!(g.d_ff, c.d_ff, "{}", c.name);
        }
    }
}

#[test]
fn kernels_three_way_agreement() {
    let Some(engine) = try_engine() else { return };
    let n = pamm::experiments::validate_kernels(&engine).expect("kernel validation");
    assert!(n >= 5, "expected several kernel artifacts, got {n}");
}

#[test]
fn nano_training_learns_through_full_stack() {
    let Some(engine) = try_engine() else { return };
    let cfg = RunConfig {
        model: "nano".into(),
        variant: Variant::pamm(64),
        batch: 4,
        seq: 64,
        steps: 25,
        eval_every: 0,
        run_dir: std::env::temp_dir().join("pamm_e2e_runs").to_str().unwrap().into(),
        ..Default::default()
    };
    let out = train_run(&engine, &cfg, true).expect("train");
    // ln(256) ≈ 5.55 at init; 25 steps must cut loss substantially.
    assert!(out.final_loss < 5.2, "loss {}", out.final_loss);
    assert!(out.curve.first().unwrap().1 > out.final_loss);
    let eval = out.final_eval_loss.expect("eval artifact present");
    assert!(eval < 5.5, "eval loss {eval}");
}

#[test]
fn training_is_deterministic_per_seed() {
    let Some(engine) = try_engine() else { return };
    let mk = |seed| {
        let name = "train_nano_pamm64_4x64";
        let mut s = TrainSession::new(&engine, name, None, seed).unwrap();
        let mut it = BatchIterator::from_seed(256, 4, 64, 7);
        let mut losses = Vec::new();
        for _ in 0..5 {
            losses.push(s.step(&it.next_batch().to_tensor()).unwrap());
        }
        losses
    };
    assert_eq!(mk(1), mk(1));
    assert_ne!(mk(1), mk(2));
}

#[test]
fn pallas_variant_matches_ref_variant_exactly() {
    // The pamm64 and pamm64pl artifacts implement the same math (jnp ref
    // vs Pallas kernels); with identical seeds the training trajectories
    // must agree to float tolerance.
    let Some(engine) = try_engine() else { return };
    let run = |name: &str| {
        let mut s = TrainSession::new(&engine, name, None, 3).unwrap();
        let mut it = BatchIterator::from_seed(256, 4, 64, 11);
        let mut losses = Vec::new();
        for _ in 0..4 {
            losses.push(s.step(&it.next_batch().to_tensor()).unwrap());
        }
        losses
    };
    let ref_losses = run("train_nano_pamm64_4x64");
    let pl_losses = run("train_nano_pamm64pl_4x64");
    for (a, b) in ref_losses.iter().zip(&pl_losses) {
        assert!((a - b).abs() < 2e-3, "ref {a} vs pallas {b}");
    }
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some(engine) = try_engine() else { return };
    let dir = std::env::temp_dir().join("pamm_ckpt_e2e");
    let mut s =
        TrainSession::new(&engine, "train_nano_pamm64_4x64", Some("eval_nano_4x64"), 5).unwrap();
    let mut it = BatchIterator::from_seed(256, 4, 64, 5);
    for _ in 0..6 {
        s.step(&it.next_batch().to_tensor()).unwrap();
    }
    let eval_batches: Vec<_> = (0..2).map(|_| it.next_batch().to_tensor()).collect();
    let loss_before = s.eval(&eval_batches).unwrap();
    let params = s.params_host().unwrap();
    checkpoint::save(&dir, "t", &params).unwrap();

    let mut s2 =
        TrainSession::new(&engine, "train_nano_pamm64_4x64", Some("eval_nano_4x64"), 99).unwrap();
    let loaded = checkpoint::load(&dir, "t").unwrap();
    s2.load_params(&loaded).unwrap();
    let loss_after = s2.eval(&eval_batches).unwrap();
    assert!((loss_before - loss_after).abs() < 1e-5, "{loss_before} vs {loss_after}");
}

#[test]
fn ddp_single_worker_matches_expected_convergence() {
    let Some(engine) = try_engine() else { return };
    let mut t = DdpTrainer::new(
        &engine,
        "grads_nano_pamm64_4x64",
        "apply_nano_pamm64_4x64",
        1,
        42,
    )
    .expect("ddp artifacts");
    let first = t.step(1).unwrap();
    let mut last = first;
    for _ in 0..14 {
        last = t.step(1).unwrap();
    }
    assert!(last < first - 0.2, "ddp loss {first} → {last}");
}

#[test]
fn ddp_multi_worker_accumulation_converges() {
    let Some(engine) = try_engine() else { return };
    let mut t = DdpTrainer::new(
        &engine,
        "grads_nano_pamm64_4x64",
        "apply_nano_pamm64_4x64",
        2,
        43,
    )
    .unwrap();
    assert_eq!(t.tokens_per_step(2), 2 * 2 * 4 * 64);
    let first = t.step(2).unwrap();
    let mut last = first;
    for _ in 0..7 {
        last = t.step(2).unwrap();
    }
    assert!(last < first, "ddp accum loss {first} → {last}");
}

#[test]
fn wrong_shape_inputs_are_rejected() {
    let Some(engine) = try_engine() else { return };
    let mut s = TrainSession::new(&engine, "train_nano_pamm64_4x64", None, 1).unwrap();
    let bad = pamm::runtime::HostTensor::i32(vec![2, 65], vec![0; 130]);
    assert!(s.step(&bad).is_err());
}

#[test]
fn engine_rejects_unknown_artifact() {
    let Some(engine) = try_engine() else { return };
    assert!(engine.executable("does_not_exist").is_err());
}
