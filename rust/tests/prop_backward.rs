//! Property tests for the compressed-activation backward
//! (`crate::autograd` + the attention/pamm backward entry points):
//! finite-difference gradient check against an independent f64 oracle
//! on ragged tile shapes, scalar==sse2==avx2 bit-equality of the
//! gradients, 1/2/4-thread parity, all-generators backward == exact
//! dense backward, and the measured saved-for-backward / peak bounds.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both) — the explicit-dispatch assertions additionally
//! sweep the whole ladder inside one process.

use pamm::attention::{self, AttnShape, BR};
use pamm::autograd::{self, QkvAttnSaved};
use pamm::memory::MemoryLedger;
use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::{self, Dispatch};
use pamm::tensor::Mat;

fn rand_mat(rows: usize, cols: usize, std: f32, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    Mat::random_normal(rows, cols, std, &mut rng)
}

fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    let mut v = vec![0f32; len];
    rng.fill_normal_f32(&mut v, 1.0);
    v
}

fn to_f64(m: &Mat) -> Vec<f64> {
    m.data().iter().map(|&x| x as f64).collect()
}

/// f64 matmul: (r×k)·(k×c), plain triple loop.
fn mm64(a: &[f64], b: &[f64], r: usize, k: usize, c: usize) -> Vec<f64> {
    let mut out = vec![0f64; r * c];
    for i in 0..r {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..c {
                out[i * c + j] += av * b[p * c + j];
            }
        }
    }
    out
}

/// Independent f64 oracle of the whole compressed forward + MSE loss:
/// project the RECONSTRUCTED Ã densely, materialized-scores softmax
/// attention, loss vs `target`. Deliberately shares no tiling, no
/// online softmax and no gather-scale with the implementation.
fn oracle_loss(
    atilde: &[f64],
    wq: &[f64],
    wk: &[f64],
    wv: &[f64],
    shape: &AttnShape,
    target: &[f32],
) -> f64 {
    let tokens = shape.tokens();
    let dm = shape.d_model();
    let (bh, l, d) = (shape.batch * shape.heads, shape.seq, shape.head_dim);
    let qp = mm64(atilde, wq, tokens, dm, dm);
    let kp = mm64(atilde, wk, tokens, dm, dm);
    let vp = mm64(atilde, wv, tokens, dm, dm);
    // split_heads in f64: (tokens × dm) -> (batch, heads, seq, d).
    let split = |m: &[f64]| -> Vec<f64> {
        let mut out = vec![0f64; shape.qkv_len()];
        for b in 0..shape.batch {
            for i in 0..l {
                for h in 0..shape.heads {
                    for c in 0..d {
                        out[((b * shape.heads + h) * l + i) * d + c] =
                            m[(b * l + i) * dm + h * d + c];
                    }
                }
            }
        }
        out
    };
    let (q, k, v) = (split(&qp), split(&kp), split(&vp));
    let scale = 1.0 / (d as f64).sqrt();
    let mut loss = 0f64;
    let n = shape.qkv_len() as f64;
    for t in 0..bh {
        let off = t * l * d;
        for i in 0..l {
            let jmax = if shape.causal { i + 1 } else { l };
            let mut scores = vec![0f64; jmax];
            for (j, s) in scores.iter_mut().enumerate() {
                let mut acc = 0f64;
                for c in 0..d {
                    acc += q[off + i * d + c] * k[off + j * d + c];
                }
                *s = scale * acc;
            }
            let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0f64;
            for s in scores.iter_mut() {
                *s = (*s - mx).exp();
                sum += *s;
            }
            for c in 0..d {
                let mut acc = 0f64;
                for (j, p) in scores.iter().enumerate() {
                    acc += p * v[off + j * d + c];
                }
                let e = acc / sum - target[off + i * d + c] as f64;
                loss += e * e;
            }
        }
    }
    loss / (2.0 * n)
}

/// Run the native training fwd+bwd at an explicit dispatch level.
fn run_fwd_bwd(
    d: Dispatch,
    x: &Mat,
    wq: &Mat,
    wk: &Mat,
    wv: &Mat,
    idx: &[usize],
    shape: &AttnShape,
    target: &[f32],
    pool: &Pool,
    need_dx: bool,
) -> (Vec<f32>, QkvAttnSaved, autograd::QkvGrads) {
    let (out, saved) =
        autograd::qkv_attn_forward_on(d, x, wq, wk, wv, idx, Eps::Inf, shape, pool, None);
    let (_, dout) = autograd::mse_loss(&out, target);
    let grads =
        autograd::qkv_attn_backward_on(d, &saved, wq, wk, wv, &out, &dout, need_dx, pool, None);
    (out, saved, grads)
}

#[test]
fn finite_difference_gradient_check_against_the_f64_oracle() {
    // Ragged shapes: a tiny dense-FD shape and a Br-crossing one whose
    // entries are subsampled. Eps::Inf + gaussian rows ⇒ β = 1 exactly,
    // so the analytic dW = Ãᵀ·dY is the true gradient of the
    // compressed forward (the function the oracle differentiates).
    let cases = [
        (AttnShape::new(1, 2, 10, 4, true), 5usize, 1usize),
        (AttnShape::new(1, 1, BR + 1, 6, false), 30, 7),
    ];
    for (ci, &(shape, k, stride)) in cases.iter().enumerate() {
        let seed = 1000 + 10 * ci as u64;
        let dm = shape.d_model();
        let x = rand_mat(shape.tokens(), dm, 1.0, seed);
        let wq = rand_mat(dm, dm, 0.3, seed + 1);
        let wk = rand_mat(dm, dm, 0.3, seed + 2);
        let wv = rand_mat(dm, dm, 0.3, seed + 3);
        let mut rng = Xoshiro256::new(seed + 4);
        let idx = pammc::sample_generators(&mut rng, shape.tokens(), k);
        let target = rand_vec(shape.qkv_len(), seed + 5);
        let pool = Pool::serial();

        let comp = pammc::compress_with(&x, &idx, Eps::Inf, &pool);
        assert_eq!(comp.beta, 1.0, "no dropped rows expected at ε = ∞");
        let atilde = to_f64(&comp.reconstruct());
        let (_, _, grads) = run_fwd_bwd(
            kernels::active(),
            &x,
            &wq,
            &wk,
            &wv,
            &idx,
            &shape,
            &target,
            &pool,
            false,
        );

        let h = 1e-4f64;
        let mut w64: Vec<Vec<f64>> = vec![to_f64(&wq), to_f64(&wk), to_f64(&wv)];
        let analytic = [(&grads.dwq, "wq"), (&grads.dwk, "wk"), (&grads.dwv, "wv")];
        for (wi, &(g, name)) in analytic.iter().enumerate() {
            let entries: Vec<usize> = (0..dm * dm).step_by(stride).collect();
            let mut fds = Vec::with_capacity(entries.len());
            for &e in &entries {
                let orig = w64[wi][e];
                w64[wi][e] = orig + h;
                let lp = oracle_loss(&atilde, &w64[0], &w64[1], &w64[2], &shape, &target);
                w64[wi][e] = orig - h;
                let lm = oracle_loss(&atilde, &w64[0], &w64[1], &w64[2], &shape, &target);
                w64[wi][e] = orig;
                fds.push((lp - lm) / (2.0 * h));
            }
            let fd_scale = fds.iter().map(|f| f.abs()).fold(0f64, f64::max).max(1e-4);
            for (&e, &fd) in entries.iter().zip(&fds) {
                let gv = g.data()[e] as f64;
                assert!(
                    (gv - fd).abs() <= 2e-2 * fd_scale,
                    "case {ci} {name} entry {e}: analytic {gv} vs fd {fd} (scale {fd_scale})"
                );
            }
        }
    }
}

#[test]
fn gradients_are_bit_identical_across_dispatch_levels() {
    let shape = AttnShape::new(2, 2, BR + 3, 16, true);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 1.0, 2000);
    let wq = rand_mat(dm, dm, 0.1, 2001);
    let wk = rand_mat(dm, dm, 0.1, 2002);
    let wv = rand_mat(dm, dm, 0.1, 2003);
    let mut rng = Xoshiro256::new(2004);
    let idx = pammc::sample_generators(&mut rng, shape.tokens(), 20);
    let target = rand_vec(shape.qkv_len(), 2005);
    let pool = Pool::serial();

    let (out_b, saved_b, g_b) =
        run_fwd_bwd(Dispatch::Scalar, &x, &wq, &wk, &wv, &idx, &shape, &target, &pool, true);
    for d in [Dispatch::Sse2, Dispatch::Avx2] {
        if !d.available() {
            continue;
        }
        let (out, saved, g) =
            run_fwd_bwd(d, &x, &wq, &wk, &wv, &idx, &shape, &target, &pool, true);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&out), bits(&out_b), "{}: fwd out", d.name());
        assert_eq!(bits(&saved.lse), bits(&saved_b.lse), "{}: lse", d.name());
        for (got, want, name) in [
            (&g.dwq, &g_b.dwq, "dwq"),
            (&g.dwk, &g_b.dwk, "dwk"),
            (&g.dwv, &g_b.dwv, "dwv"),
            (g.dx.as_ref().unwrap(), g_b.dx.as_ref().unwrap(), "dx"),
        ] {
            assert_eq!(bits(got.data()), bits(want.data()), "{}: {name}", d.name());
        }
    }
}

#[test]
fn fast_tier_gradients_stay_within_the_tolerance_oracle() {
    // The FMA tier's backward contract: every gradient agrees with the
    // scalar oracle's within the relative-tolerance bound. The depth
    // fed to the bound reflects the composition — forward recompute plus
    // the 5-GEMM backward tile walk chain several accumulations of
    // length ≤ tokens/seq/head_dim, so the single-chain depth is scaled
    // by the chain count.
    let shape = AttnShape::new(2, 2, BR + 3, 16, true);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 1.0, 4000);
    let wq = rand_mat(dm, dm, 0.1, 4001);
    let wk = rand_mat(dm, dm, 0.1, 4002);
    let wv = rand_mat(dm, dm, 0.1, 4003);
    let mut rng = Xoshiro256::new(4004);
    let idx = pammc::sample_generators(&mut rng, shape.tokens(), 20);
    let target = rand_vec(shape.qkv_len(), 4005);
    let pool = Pool::serial();

    let (out_b, _, g_b) =
        run_fwd_bwd(Dispatch::Scalar, &x, &wq, &wk, &wv, &idx, &shape, &target, &pool, true);
    let depth = 4 * (shape.tokens() + shape.seq + shape.head_dim);
    for d in kernels::FAST_TIER {
        if !d.available() {
            continue;
        }
        let (out, _, g) =
            run_fwd_bwd(d, &x, &wq, &wk, &wv, &idx, &shape, &target, &pool, true);
        kernels::tol_check(&out, &out_b, depth)
            .unwrap_or_else(|e| panic!("{} fwd out: {e}", d.name()));
        for (got, want, name) in [
            (&g.dwq, &g_b.dwq, "dwq"),
            (&g.dwk, &g_b.dwk, "dwk"),
            (&g.dwv, &g_b.dwv, "dwv"),
            (g.dx.as_ref().unwrap(), g_b.dx.as_ref().unwrap(), "dx"),
        ] {
            kernels::tol_check(got.data(), want.data(), depth)
                .unwrap_or_else(|e| panic!("{} {name}: {e}", d.name()));
        }
    }
}

#[test]
fn gradients_are_bit_identical_across_thread_counts() {
    let shape = AttnShape::new(2, 4, BR - 1, 17, false);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 1.0, 3000);
    let wq = rand_mat(dm, dm, 0.1, 3001);
    let wk = rand_mat(dm, dm, 0.1, 3002);
    let wv = rand_mat(dm, dm, 0.1, 3003);
    let mut rng = Xoshiro256::new(3004);
    let idx = pammc::sample_generators(&mut rng, shape.tokens(), 24);
    let target = rand_vec(shape.qkv_len(), 3005);
    let d = kernels::active();

    let (out_b, saved_b, g_b) =
        run_fwd_bwd(d, &x, &wq, &wk, &wv, &idx, &shape, &target, &Pool::serial(), true);
    for threads in [2usize, 4] {
        let pool = Pool::new(threads);
        let (out, saved, g) =
            run_fwd_bwd(d, &x, &wq, &wk, &wv, &idx, &shape, &target, &pool, true);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&out), bits(&out_b), "t={threads}: fwd out");
        assert_eq!(bits(&saved.lse), bits(&saved_b.lse), "t={threads}: lse");
        for (got, want, name) in [
            (&g.dwq, &g_b.dwq, "dwq"),
            (&g.dwk, &g_b.dwk, "dwk"),
            (&g.dwv, &g_b.dwv, "dwv"),
            (g.dx.as_ref().unwrap(), g_b.dx.as_ref().unwrap(), "dx"),
        ] {
            assert_eq!(bits(got.data()), bits(want.data()), "t={threads}: {name}");
        }
    }
}

#[test]
fn all_generators_backward_matches_the_exact_dense_backward() {
    // Every row a generator ⇒ Ã = X (α = 1 up to Lemma-1 rounding),
    // β = 1 — the fused backward must reproduce the exact dense
    // backward: dense flash bwd slabs, merged, dW = XᵀdYᵖ.
    let shape = AttnShape::new(2, 2, 33, 8, true);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 1.0, 4000);
    let wq = rand_mat(dm, dm, 0.1, 4001);
    let wk = rand_mat(dm, dm, 0.1, 4002);
    let wv = rand_mat(dm, dm, 0.1, 4003);
    let idx: Vec<usize> = (0..shape.tokens()).collect();
    let target = rand_vec(shape.qkv_len(), 4004);
    let pool = Pool::serial();
    let d = kernels::active();

    let (out, _, grads) =
        run_fwd_bwd(d, &x, &wq, &wk, &wv, &idx, &shape, &target, &pool, true);
    let (_, dout) = autograd::mse_loss(&out, &target);

    // Exact dense reference from the same x / weights / dout.
    let q = attention::split_heads(&x.matmul(&wq), &shape);
    let k = attention::split_heads(&x.matmul(&wk), &shape);
    let v = attention::split_heads(&x.matmul(&wv), &shape);
    let (o_d, lse_d) = attention::flash_attention_fwd_on(d, &q, &k, &v, &shape, &pool);
    let (dq, dk, dv) =
        attention::flash_attention_bwd_on(d, &q, &k, &v, &o_d, &dout, &lse_d, &shape, &pool);
    let dqp = attention::merge_heads(&dq, &shape);
    let dkp = attention::merge_heads(&dk, &shape);
    let dvp = attention::merge_heads(&dv, &shape);
    let close = |got: &Mat, want: &Mat, name: &str| {
        let scale = want.frob_norm().max(1e-6);
        assert!(
            got.max_abs_diff(want) <= 1e-3 * scale,
            "{name}: diff {} vs scale {scale}",
            got.max_abs_diff(want)
        );
    };
    close(&grads.dwq, &x.t_matmul(&dqp), "dwq");
    close(&grads.dwk, &x.t_matmul(&dkp), "dwk");
    close(&grads.dwv, &x.t_matmul(&dvp), "dwv");
    let mut dx = dqp.matmul(&wq.transpose());
    dx.add_assign(&dkp.matmul(&wk.transpose()));
    dx.add_assign(&dvp.matmul(&wv.transpose()));
    close(grads.dx.as_ref().unwrap(), &dx, "dx");
}

#[test]
fn measured_saved_and_peaks_respect_the_analytic_bounds() {
    // The acceptance invariant: saved-for-backward is EXACTLY
    // Compressed + lse, at least 4× below the dense baseline at this
    // shape, and the tracked fwd/bwd transient peaks stay under their
    // analytic bounds. Fresh pool ⇒ cold worker TLS.
    let shape = AttnShape::new(2, 2, 256, 32, true);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 1.0, 5000);
    let wq = rand_mat(dm, dm, 0.1, 5001);
    let wk = rand_mat(dm, dm, 0.1, 5002);
    let wv = rand_mat(dm, dm, 0.1, 5003);
    let mut rng = Xoshiro256::new(5004);
    let idx = pammc::sample_generators(&mut rng, shape.tokens(), 24);
    let target = rand_vec(shape.qkv_len(), 5005);

    let threads = 2usize;
    let pool = Pool::new(threads);
    let ledger = MemoryLedger::new();
    let d = kernels::active();
    let (out, saved) = autograd::qkv_attn_forward_on(
        d,
        &x,
        &wq,
        &wk,
        &wv,
        &idx,
        Eps::Inf,
        &shape,
        &pool,
        Some(&ledger),
    );
    assert_eq!(
        saved.saved_bytes(),
        saved.comp.stored_bytes() + saved.lse.len() * 4,
        "saved inventory is Compressed + statistics, nothing else"
    );
    assert_eq!(ledger.saved(), saved.saved_bytes());
    let dense = autograd::dense_saved_bytes(dm, &shape);
    assert!(
        ledger.saved() * 4 <= dense,
        "saved {} not ≥4x below dense {dense}",
        ledger.saved()
    );
    let fwd_bound = attention::fused_peak_bound(&saved.comp, &shape, threads);
    assert!(ledger.forward.peak() > 0, "forward must charge transients");
    assert!(
        ledger.forward.peak() <= fwd_bound,
        "fwd peak {} exceeds bound {fwd_bound}",
        ledger.forward.peak()
    );

    let (_, dout) = autograd::mse_loss(&out, &target);
    autograd::qkv_attn_backward_on(
        d,
        &saved,
        &wq,
        &wk,
        &wv,
        &out,
        &dout,
        false,
        &pool,
        Some(&ledger),
    );
    let bwd_bound = autograd::backward_peak_bound(
        saved.comp.k(),
        saved.comp.generators.cols(),
        &shape,
        threads,
        false,
    );
    assert!(ledger.backward.peak() > 0, "backward must charge transients");
    assert!(
        ledger.backward.peak() <= bwd_bound,
        "bwd peak {} exceeds bound {bwd_bound}",
        ledger.backward.peak()
    );
    // Backward transients are allowed to be activation-sized (the
    // gradient slabs are genuine outputs) — the headline claim is the
    // saved column, which the ledger renders against the dense row.
    let table = ledger.render(dense);
    assert!(table.contains("saved for backward"), "{table}");
}
