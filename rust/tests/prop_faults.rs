//! Fault-tolerance property tests (DESIGN.md §9, EXPERIMENTS.md P15):
//!
//! * **Kill-anywhere bit-parity** — a supervised run killed at EVERY
//!   checkpoint boundary × every crash phase (before / mid-write /
//!   after the checkpoint) recovers to a final checkpoint AND a
//!   replayed run log bitwise identical to the uninterrupted run's.
//! * **Checksum + ring fallback** — scripted bitrot in the newest ring
//!   entry is detected by the CRC layer, reported as a diagnostic, and
//!   recovery falls back to the previous verifying entry (truncation
//!   behaves the same); the run still converges bitwise.
//! * **Quarantine isolation** — a poisoned serve session is retired
//!   with its clean token prefix while every surviving stream stays
//!   bit-identical to the fault-free baseline at 1/2/4 workers.
//! * **Degradation determinism** — shed / truncation / timeout
//!   decisions under a burst load are pure functions of the script,
//!   identical at every worker count.
//! * **Plan replay** — a `FaultPlan` is a pure function of its seed:
//!   the same seed reproduces the identical campaign.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both).

use std::path::PathBuf;

use pamm::checkpoint::{self, CheckpointRing};
use pamm::coordinator::{
    checkpoint_boundaries, scripted_load, serve, serve_faulted, train_lm_native_run,
    train_lm_supervised, LmRunConfig, NativeOpt, ServeConfig, SessionStatus,
};
use pamm::faultx::{CrashPhase, FaultPlan, TrainFault};
use pamm::metrics::replay_run_log;
use pamm::model::{LmConfig, TransformerLM};
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::runtime::HostTensor;

fn scratch(test: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pamm_prop_faults_{}_{test}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn train_rc(dir: &std::path::Path, run_name: &str) -> LmRunConfig {
    LmRunConfig {
        cfg: LmConfig { vocab: 120, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 },
        batch: 1,
        seq: 8,
        steps: 8,
        k: 4,
        opt: NativeOpt::adam(3e-3),
        seed: 33,
        ckpt_every: 2,
        keep_last: 3,
        run_dir: dir.join(run_name).to_string_lossy().into_owned(),
        run_name: run_name.to_string(),
        resume: false,
    }
}

fn final_tensors(rc: &LmRunConfig) -> Vec<(String, HostTensor)> {
    checkpoint::load(format!("{}/ckpt", rc.run_dir), &rc.run_name).expect("final checkpoint")
}

fn replayed(rc: &LmRunConfig) -> Vec<(usize, u64)> {
    replay_run_log(&rc.run_dir, &rc.run_name)
        .expect("replay run log")
        .into_iter()
        .map(|(s, l)| (s, l.to_bits()))
        .collect()
}

#[test]
fn recovery_is_bitwise_identical_at_every_kill_point_and_phase() {
    let dir = scratch("kill_sweep");
    let pool = Pool::serial();
    let base_rc = train_rc(&dir, "base");
    train_lm_native_run(&base_rc, None, &pool, true).unwrap();
    let base_final = final_tensors(&base_rc);
    let base_log = replayed(&base_rc);
    let boundaries = checkpoint_boundaries(&base_rc);
    assert_eq!(boundaries, vec![2, 4, 6, 8]);

    for (i, plan) in FaultPlan::every_boundary(33, &boundaries).iter().enumerate() {
        let f = plan.crashes[0];
        let rc = train_rc(&dir, &format!("kill_{i}"));
        let out = train_lm_supervised(&rc, plan, &pool, true)
            .unwrap_or_else(|e| panic!("kill s{}/{}: {e:#}", f.step, f.phase.name()));
        assert_eq!(
            out.crashes.len(),
            1,
            "kill s{}/{} never fired",
            f.step,
            f.phase.name()
        );
        assert_eq!(out.crashes[0].step, f.step);
        assert_eq!(out.attempts, 2, "one crash ⇒ exactly one recovery launch");
        assert_eq!(
            final_tensors(&rc),
            base_final,
            "kill s{}/{}: final checkpoint drifted",
            f.step,
            f.phase.name()
        );
        assert_eq!(
            replayed(&rc),
            base_log,
            "kill s{}/{}: replayed run log drifted",
            f.step,
            f.phase.name()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_newest_checkpoint_is_detected_and_recovery_falls_back() {
    let dir = scratch("corruption");
    let pool = Pool::serial();
    let base_rc = train_rc(&dir, "base");
    train_lm_native_run(&base_rc, None, &pool, true).unwrap();
    let base_final = final_tensors(&base_rc);

    // Kill right after the step-4 checkpoint landed, then bit-flip it:
    // recovery must detect the flip (CRC), skip the entry with a
    // diagnostic, and resume from the step-2 entry instead.
    let rc = train_rc(&dir, "corrupt");
    let plan = {
        let mut p = FaultPlan::new(33);
        p.crashes.push(TrainFault { step: 4, phase: CrashPhase::AfterCheckpoint });
        p.with_corruption(0)
    };
    let out = train_lm_supervised(&rc, &plan, &pool, true).unwrap();
    assert!(
        out.recovery_diags.iter().any(|d| d.contains("injected corruption")),
        "corruption injection missing from diags: {:?}",
        out.recovery_diags
    );
    assert!(
        out.recovery_diags.iter().any(|d| d.contains("failed verification")),
        "CRC never flagged the flipped entry: {:?}",
        out.recovery_diags
    );
    assert_eq!(out.resume_steps, vec![2], "must fall back past the corrupt step-4 entry");
    assert_eq!(final_tensors(&rc), base_final, "post-fallback run drifted from baseline");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_ring_entry_falls_back_without_panicking() {
    let dir = scratch("truncation");
    let pool = Pool::serial();
    let rc = train_rc(&dir, "trunc");
    train_lm_native_run(&rc, None, &pool, true).unwrap();

    let ring = CheckpointRing::new(format!("{}/ckpt", rc.run_dir), &rc.run_name, rc.keep_last);
    let entries = ring.entries();
    assert_eq!(entries.len(), 3, "keep_last=3 must retain 3 of the 4 boundary entries");
    let &(newest, _) = entries.last().unwrap();
    let blob = ring.blob_path(newest);
    let bytes = std::fs::read(&blob).unwrap();
    std::fs::write(&blob, &bytes[..bytes.len() / 2]).unwrap();

    let (found, diags) = ring.load_latest_good();
    let (step, tensors) = found.expect("older entries must still verify");
    assert_eq!(step, entries[entries.len() - 2].0, "fallback target is the next-newest entry");
    assert!(!tensors.is_empty());
    assert_eq!(diags.len(), 1);
    assert!(diags[0].contains("failed verification"), "{diags:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

fn serve_model() -> TransformerLM {
    TransformerLM::new(LmConfig { vocab: 53, n_layers: 2, heads: 2, head_dim: 8, d_ff: 24 }, 41)
}

#[test]
fn quarantine_leaves_every_surviving_stream_bitwise_unchanged() {
    let model = serve_model();
    let cfg = ServeConfig::new(3, 4, Eps::Inf, 2718);
    let reqs = scripted_load(8, model.cfg.vocab, 7);
    let clean = serve(&model, &cfg, &reqs, &Pool::serial()).unwrap();

    let sessions: Vec<(usize, usize)> = reqs.iter().map(|r| (r.id, r.max_new)).collect();
    let plan = FaultPlan::new(77).sample_poison(&sessions, 2);
    assert_eq!(plan.poison.len(), 2);

    let mut last: Option<Vec<(usize, Vec<i32>)>> = None;
    for workers in [1usize, 2, 4] {
        let pool =
            if workers == 1 { Pool::serial() } else { Pool::new(workers).with_min_chunk(1) };
        let out = serve_faulted(&model, &cfg, &reqs, Some(&plan), &pool).unwrap();
        assert_eq!(out.completions.len(), reqs.len(), "every request must be accounted for");
        assert_eq!(out.count(SessionStatus::Quarantined), 2, "at {workers} workers");
        for c in &out.completions {
            let base = clean.completions.iter().find(|k| k.id == c.id).unwrap();
            match plan.poison_for(c.id) {
                Some(site) => {
                    assert_eq!(c.status, SessionStatus::Quarantined, "id {}", c.id);
                    assert_eq!(c.tokens.len(), site.after_tokens, "id {}", c.id);
                    assert_eq!(
                        c.tokens[..],
                        base.tokens[..site.after_tokens],
                        "id {}: quarantined stream must be the clean prefix",
                        c.id
                    );
                    assert!(c.diag.as_deref().unwrap_or("").contains("non-finite"));
                }
                None => {
                    assert_eq!(c.status, SessionStatus::Ok, "id {}", c.id);
                    assert_eq!(
                        c.tokens, base.tokens,
                        "id {}: survivor drifted at {workers} workers",
                        c.id
                    );
                }
            }
        }
        let streams: Vec<(usize, Vec<i32>)> =
            out.completions.iter().map(|c| (c.id, c.tokens.clone())).collect();
        if let Some(prev) = &last {
            assert_eq!(&streams, prev, "faulted schedule drifted at {workers} workers");
        }
        last = Some(streams);
    }
}

#[test]
fn shed_truncate_and_timeout_decisions_are_worker_count_invariant() {
    let model = serve_model();
    let reqs: Vec<pamm::coordinator::ServeRequest> = scripted_load(8, model.cfg.vocab, 11)
        .into_iter()
        .map(|mut r| {
            r.arrival = 0; // burst: everyone at once
            r
        })
        .collect();
    let mut cfg = ServeConfig::new(1, 4, Eps::Inf, 5);
    cfg.max_queue = 2;
    cfg.token_budget = 3;
    cfg.deadline_steps = 2;

    let fingerprint = |out: &pamm::coordinator::ServeOutcome| {
        (
            out.shed.iter().map(|s| (s.id, s.shed_step)).collect::<Vec<_>>(),
            out.completions
                .iter()
                .map(|c| (c.id, c.status, c.tokens.clone(), c.admitted_step, c.finished_step))
                .collect::<Vec<_>>(),
        )
    };
    let serial = serve(&model, &cfg, &reqs, &Pool::serial()).unwrap();
    assert!(!serial.shed.is_empty(), "queue of 2 must shed under an 8-request burst");
    assert_eq!(serial.completions.len() + serial.shed.len(), reqs.len());
    // Budget 3 < every requested max_new (≥ 4), deadline 2 < budget 3:
    // every admitted session times out at 2 tokens before truncation.
    for c in &serial.completions {
        assert_eq!(c.status, SessionStatus::TimedOut, "id {}", c.id);
        assert_eq!(c.tokens.len(), 2, "id {}", c.id);
    }
    for workers in [2usize, 4] {
        let out = serve(&model, &cfg, &reqs, &Pool::new(workers).with_min_chunk(1)).unwrap();
        assert_eq!(
            fingerprint(&out),
            fingerprint(&serial),
            "degradation decisions drifted at {workers} workers"
        );
    }
}

#[test]
fn fault_plans_replay_identically_from_their_seed() {
    let boundaries = [2usize, 4, 6, 8];
    let sessions: Vec<(usize, usize)> = (0..6).map(|i| (i, 4 + i % 5)).collect();
    let a = FaultPlan::sample_train(99, &boundaries, 2).sample_poison(&sessions, 2);
    let b = FaultPlan::sample_train(99, &boundaries, 2).sample_poison(&sessions, 2);
    assert_eq!(a, b, "same seed must reproduce the identical campaign");
    let c = FaultPlan::sample_train(100, &boundaries, 2).sample_poison(&sessions, 2);
    assert!(
        a != c || a.crashes.is_empty(),
        "different seeds should not collide on this tiny space"
    );
    // Structural guarantees the supervisor and serve loop rely on.
    assert!(a.crashes.windows(2).all(|w| w[0].step < w[1].step), "crashes ascending");
    for s in &a.poison {
        let (_, max_new) = sessions[s.id];
        assert!(s.after_tokens >= 1 && s.after_tokens <= max_new - 2);
    }
}

#[test]
fn malformed_requests_never_reach_a_session() {
    let model = serve_model();
    let cfg = ServeConfig::new(2, 4, Eps::Inf, 3);
    let reqs = vec![
        pamm::coordinator::ServeRequest { id: 0, arrival: 0, prompt: vec![], max_new: 4 },
        pamm::coordinator::ServeRequest { id: 1, arrival: 0, prompt: vec![1, 2], max_new: 0 },
        pamm::coordinator::ServeRequest { id: 2, arrival: 0, prompt: vec![1, -7], max_new: 4 },
        pamm::coordinator::ServeRequest { id: 3, arrival: 1, prompt: vec![3, 4], max_new: 4 },
    ];
    let out = serve(&model, &cfg, &reqs, &Pool::serial()).unwrap();
    assert_eq!(out.count(SessionStatus::Rejected), 3);
    assert_eq!(out.count(SessionStatus::Ok), 1);
    let ok = out.completions.iter().find(|c| c.status == SessionStatus::Ok).unwrap();
    assert_eq!(ok.id, 3);
    assert_eq!(ok.tokens.len(), 4);
}
