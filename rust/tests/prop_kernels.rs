//! Property tests for the `tensor::kernels` GEMM subsystem: edge-shape
//! correctness against an f64 reference, bit-equality across SIMD
//! dispatch levels, and bit-equality across 1/2/4 worker threads — the
//! determinism contract DESIGN.md §kernels promises, exercised on
//! ragged tails around every tile boundary (MR/NR/KC/MC/NC ± 1) and on
//! empty matrices.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does) — the Mat-level assertions then cover both global dispatch
//! modes, while the explicit-dispatch assertions cover the whole ladder
//! in a single process regardless of the env var.

use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::{self, Dispatch, PackBufs, Tiles, KC, MC, MR, NC, NR};
use pamm::tensor::Mat;

fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    Mat::random_normal(rows, cols, 1.0, &mut rng)
}

/// f64-accumulated reference product (order-insensitive up to f64
/// rounding, which is far below the f32 comparison tolerance).
fn naive_matmul(a: &Mat, b: &Mat) -> Vec<f32> {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..k {
                acc += a.get(i, p) as f64 * b.get(p, j) as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

fn explicit_gemm(d: Dispatch, trans_a: bool, a: &Mat, b: &Mat) -> Vec<f32> {
    let (m, kdim) = if trans_a { (a.cols(), a.rows()) } else { (a.rows(), a.cols()) };
    let n = b.cols();
    assert_eq!(kdim, b.rows());
    let mut c = vec![0f32; m * n];
    let mut packs = PackBufs::default();
    let lda = a.cols();
    kernels::gemm_into(d, trans_a, m, n, kdim, a.data(), lda, b.data(), n, &mut c, n, &mut packs);
    c
}

/// The edge-shape ladder: 1, MR−1/MR/MR+1 (= NR±… since MR = NR), a
/// non-multiple in the middle, and KC/MC/NC crossings. Kept asymmetric
/// so m/n/k misalignments can't mask each other.
fn edge_dims() -> Vec<(usize, usize, usize)> {
    vec![
        (1, 1, 1),
        (1, NR + 1, KC + 1),
        (MR - 1, NR - 1, 3),
        (MR, NR, KC),
        (MR + 1, NR + 1, KC - 1),
        (13, 7, 2 * KC + 3),   // k crosses two KC panels, ragged tiles
        (MC + 1, 9, 5),        // m crosses the MC block
        (3, NC + 1, 2),        // n crosses the NC block
        (65, 33, 17),
    ]
}

#[test]
fn gemm_matches_f64_reference_on_edge_shapes() {
    for (ix, &(m, n, k)) in edge_dims().iter().enumerate() {
        let a = rand_mat(m, k, 100 + ix as u64);
        let b = rand_mat(k, n, 200 + ix as u64);
        let want = naive_matmul(&a, &b);
        let got = a.matmul(&b);
        assert_eq!(got.rows(), m);
        assert_eq!(got.cols(), n);
        for (i, (g, w)) in got.data().iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "m={m} n={n} k={k} elem {i}: {g} vs {w}"
            );
        }
    }
}

#[test]
fn t_matmul_matches_f64_reference_on_edge_shapes() {
    for (ix, &(m, n, k)) in edge_dims().iter().enumerate() {
        // t_matmul input is stored transposed: (k, m) with k = shared dim.
        let at = rand_mat(k, m, 300 + ix as u64);
        let b = rand_mat(k, n, 400 + ix as u64);
        let want = naive_matmul(&at.transpose(), &b);
        let got = at.t_matmul(&b);
        for (i, (g, w)) in got.data().iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "tn m={m} n={n} k={k} elem {i}: {g} vs {w}"
            );
        }
    }
}

#[test]
fn every_dispatch_level_is_bit_identical_on_every_edge_shape() {
    for (ix, &(m, n, k)) in edge_dims().iter().enumerate() {
        let a = rand_mat(m, k, 500 + ix as u64);
        let b = rand_mat(k, n, 600 + ix as u64);
        let at = rand_mat(k, m, 700 + ix as u64);
        for trans_a in [false, true] {
            let lhs = if trans_a { &at } else { &a };
            let base = explicit_gemm(Dispatch::Scalar, trans_a, lhs, &b);
            for d in [Dispatch::Sse2, Dispatch::Avx2, Dispatch::native()] {
                if !d.available() {
                    continue;
                }
                let got = explicit_gemm(d, trans_a, lhs, &b);
                for (i, (g, w)) in got.iter().zip(&base).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "{} vs scalar: m={m} n={n} k={k} trans={trans_a} elem {i}",
                        d.name()
                    );
                }
            }
        }
    }
}

#[test]
fn thread_count_is_bit_invariant_on_edge_shapes() {
    for (ix, &(m, n, k)) in edge_dims().iter().enumerate() {
        let a = rand_mat(m, k, 800 + ix as u64);
        let b = rand_mat(k, n, 900 + ix as u64);
        let at = rand_mat(k, m, 950 + ix as u64);
        let serial_nn = a.matmul(&b);
        let serial_tn = at.t_matmul(&b);
        for threads in [2usize, 4] {
            let pool = Pool::new(threads).with_min_chunk(1);
            assert_eq!(a.matmul_with(&b, &pool), serial_nn, "nn m={m} n={n} k={k} t={threads}");
            assert_eq!(
                at.matmul_tn_with(&b, &pool),
                serial_tn,
                "tn m={m} n={n} k={k} t={threads}"
            );
        }
    }
}

#[test]
fn empty_matrices_are_handled() {
    let e05 = Mat::zeros(0, 5);
    let e53 = Mat::zeros(5, 3);
    assert_eq!(e05.matmul(&e53).rows(), 0);
    assert_eq!(Mat::zeros(4, 0).matmul(&Mat::zeros(0, 3)), Mat::zeros(4, 3));
    assert_eq!(e05.t_matmul(&Mat::zeros(0, 7)), Mat::zeros(5, 7));
    let pool = Pool::new(2).with_min_chunk(1);
    assert_eq!(e05.matmul_with(&e53, &pool).rows(), 0);
    assert_eq!(e05.matmul_tn_with(&Mat::zeros(0, 7), &pool), Mat::zeros(5, 7));
}

#[test]
fn fast_tier_matches_scalar_within_tolerance_on_edge_shapes() {
    // The FMA tier is not bit-identical to the ladder; its contract is
    // the k-depth relative tolerance oracle, on the same ragged tile
    // boundaries (MR±1 / KC±1 / …) the bit ladder is exercised on.
    for (ix, &(m, n, k)) in edge_dims().iter().enumerate() {
        let a = rand_mat(m, k, 1000 + ix as u64);
        let b = rand_mat(k, n, 1100 + ix as u64);
        let at = rand_mat(k, m, 1200 + ix as u64);
        for trans_a in [false, true] {
            let lhs = if trans_a { &at } else { &a };
            let base = explicit_gemm(Dispatch::Scalar, trans_a, lhs, &b);
            for d in kernels::FAST_TIER {
                if !d.available() {
                    continue;
                }
                let got = explicit_gemm(d, trans_a, lhs, &b);
                kernels::tol_check(&got, &base, k).unwrap_or_else(|e| {
                    panic!("{} m={m} n={n} k={k} trans={trans_a}: {e}", d.name())
                });
            }
        }
    }
}

#[test]
fn autotuned_tile_shapes_stay_within_the_tolerance_oracle() {
    // Non-default KC/MC/NC (the kind `--tune` installs): mc/nc are
    // bit-neutral scheduling, kc regroups the k-panel accumulation —
    // every combination must stay within the same k-depth tolerance of
    // the default-tiled scalar result, at both the bit-exact native
    // level and the fast tier.
    let tile_sets = [
        Tiles { kc: KC / 2, mc: MC, nc: NC },
        Tiles { kc: KC + 64, mc: MC / 2, nc: NC / 2 },
        Tiles { kc: 96, mc: 48, nc: 512 },
    ];
    for (ix, &(m, n, k)) in edge_dims().iter().enumerate() {
        let a = rand_mat(m, k, 1300 + ix as u64);
        let b = rand_mat(k, n, 1400 + ix as u64);
        let base = explicit_gemm(Dispatch::Scalar, false, &a, &b);
        for d in [Dispatch::native(), Dispatch::fastest()] {
            for t in tile_sets {
                let mut c = vec![0f32; m * n];
                let mut packs = PackBufs::default();
                kernels::gemm_into_tiled(
                    d,
                    t,
                    false,
                    m,
                    n,
                    k,
                    a.data(),
                    k,
                    b.data(),
                    n,
                    &mut c,
                    n,
                    &mut packs,
                );
                kernels::tol_check(&c, &base, k).unwrap_or_else(|e| {
                    panic!("{} tiles {t:?} m={m} n={n} k={k}: {e}", d.name())
                });
            }
        }
    }
}

#[test]
fn unset_pamm_simd_never_dispatches_the_fast_tier() {
    // The fast tier is strictly opt-in: with PAMM_SIMD unset (or set to
    // a ladder level), the active dispatch must stay bit-exact.
    match std::env::var("PAMM_SIMD") {
        Err(_) => assert!(
            !kernels::active().is_fast(),
            "unset PAMM_SIMD must stay on the bit-exact ladder, got {}",
            kernels::active().name()
        ),
        Ok(v) => {
            if let Some(d) = Dispatch::parse(&v) {
                if !d.is_fast() {
                    assert!(!kernels::active().is_fast());
                }
            }
        }
    }
}

#[test]
fn mat_routing_agrees_with_explicit_active_dispatch() {
    // Mat::matmul must be exactly gemm(active) — i.e. the Mat layer adds
    // no numerical behavior of its own, under whatever PAMM_SIMD says.
    let a = rand_mat(33, 29, 42);
    let b = rand_mat(29, 21, 43);
    let via_mat = a.matmul(&b);
    let explicit = explicit_gemm(kernels::active(), false, &a, &b);
    for (g, w) in via_mat.data().iter().zip(&explicit) {
        assert_eq!(g.to_bits(), w.to_bits());
    }
}
