//! Property tests for the native ε/k ablation harness
//! (`experiments::ablation`, P17 — the `pamm ablate` engine):
//!
//! * table determinism — the same (shape, grids) sweep run twice is
//!   bitwise identical, cell for cell,
//! * saved-bytes exactness — every cell's memory column equals an
//!   independently measured `MemoryLedger` inventory for the same
//!   trainer step (measured == analytic, no sampling),
//! * all-generators == dense — the (ε = ∞, k = batch·seq) cell
//!   bit-matches an independently run dense baseline,
//! * monotone memory — at fixed ε, shrinking k strictly shrinks the
//!   cell's saved bytes.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both).

use pamm::coordinator::LmTrainer;
use pamm::data::BatchIterator;
use pamm::experiments::ablation::{grids, run_cell, sweep, AblationShape};
use pamm::memory::MemoryLedger;
use pamm::model::LmConfig;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::tensor::kernels;

/// A shape small enough that a full sweep is cheap in a test, but with
/// ≥ 2 blocks and enough tokens for three k octaves (64, 8, 1).
fn test_shape() -> AblationShape {
    let mut s = AblationShape::quick();
    s.steps = 4;
    s
}

#[test]
fn sweep_is_bitwise_deterministic() {
    let shape = test_shape();
    let (eps_grid, k_grid) = grids(&shape, true);
    let pool = Pool::serial();
    let digest = |cells: &[pamm::experiments::ablation::AblationCell]| {
        cells
            .iter()
            .map(|c| (c.eps_label.clone(), c.k, c.final_loss.to_bits(), c.saved_bytes))
            .collect::<Vec<_>>()
    };
    let a = sweep(&shape, &eps_grid, &k_grid, &pool).unwrap();
    let b = sweep(&shape, &eps_grid, &k_grid, &pool).unwrap();
    assert_eq!(a.len(), eps_grid.len() * k_grid.len(), "one cell per (eps, k)");
    assert_eq!(digest(&a), digest(&b), "same seed must reproduce the table bitwise");
}

#[test]
fn saved_bytes_cells_equal_an_independent_ledger_inventory() {
    let shape = test_shape();
    let pool = Pool::serial();
    for (eps, k) in [(Eps::Inf, shape.tokens()), (Eps::Inf, 8), (Eps::Val(0.5), 8)] {
        let cell = run_cell(&shape, eps, k, &pool).unwrap();
        // Replay the cell's training run by hand and measure the final
        // step with a live ledger: the cell's memory column must equal
        // the measured inventory exactly.
        let mut t =
            LmTrainer::new(shape.cfg.clone(), shape.batch, shape.seq, k, shape.opt, shape.seed);
        t.eps = eps;
        let mut it =
            BatchIterator::from_seed(shape.cfg.vocab, shape.batch, shape.seq, shape.seed);
        for _ in 0..shape.steps - 1 {
            let b = it.next_batch();
            t.train_step(&b.tokens, &pool, None).unwrap();
        }
        let b = it.next_batch();
        let ledger = MemoryLedger::new();
        let rep = t.step_report(kernels::active(), &b.tokens, &pool, Some(&ledger)).unwrap();
        assert_eq!(
            cell.saved_bytes,
            ledger.saved(),
            "cell (eps={:?}, k={k}): table column vs measured ledger",
            eps
        );
        assert_eq!(cell.saved_bytes, rep.saved_bytes, "ledger vs tape inventory");
        assert_eq!(cell.final_loss.to_bits(), rep.loss.to_bits(), "replayed final loss");
    }
}

#[test]
fn all_generators_cell_bit_matches_the_dense_baseline() {
    let shape = test_shape();
    let n = shape.tokens();
    let pool = Pool::serial();
    let (eps_grid, k_grid) = grids(&shape, true);
    assert_eq!(k_grid[0], n, "the grid must lead with the dense all-generators column");
    let cells = sweep(&shape, &eps_grid, &k_grid, &pool).unwrap();
    let kn = cells
        .iter()
        .find(|c| c.eps_label == "inf" && c.k == n)
        .expect("sweep must contain the (inf, n) cell");
    let dense = run_cell(&shape, Eps::Inf, n, &pool).unwrap();
    assert_eq!(
        kn.final_loss.to_bits(),
        dense.final_loss.to_bits(),
        "k = batch*seq with eps = inf is the dense computation — losses must bit-match"
    );
    assert_eq!(kn.saved_bytes, dense.saved_bytes, "dense saved bytes must match too");
}

#[test]
fn saved_bytes_strictly_shrink_as_k_shrinks() {
    let shape = test_shape();
    let (eps_grid, k_grid) = grids(&shape, true);
    assert!(k_grid.len() >= 3, "need at least three k octaves for a monotonicity check");
    assert!(k_grid.windows(2).all(|w| w[0] > w[1]), "k grid must descend");
    let pool = Pool::serial();
    let cells = sweep(&shape, &eps_grid, &k_grid, &pool).unwrap();
    for eps in &eps_grid {
        let label = pamm::experiments::ablation::eps_label(*eps);
        let row: Vec<&pamm::experiments::ablation::AblationCell> =
            cells.iter().filter(|c| c.eps_label == label).collect();
        assert_eq!(row.len(), k_grid.len());
        for w in row.windows(2) {
            assert!(
                w[0].saved_bytes > w[1].saved_bytes,
                "eps={label}: saved bytes must strictly shrink, k={} gave {} vs k={} gave {}",
                w[0].k,
                w[0].saved_bytes,
                w[1].k,
                w[1].saved_bytes
            );
        }
    }
}

#[test]
fn quick_config_is_a_valid_ablation_shape() {
    // The CLI's `--quick` path must keep an n that supports the
    // documented 8× octave grid, and LmConfig sanity for the sweep.
    let shape = AblationShape::quick();
    assert!(shape.tokens() >= 64, "quick shape must allow three k octaves");
    assert_eq!(shape.cfg, LmConfig { vocab: 300, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 });
    assert!(run_cell(&shape, Eps::Inf, shape.tokens() + 1, &Pool::serial()).is_err());
    assert!(run_cell(&shape, Eps::Inf, 0, &Pool::serial()).is_err());
}
