//! Property tests (propx) over the native PAMM invariants — no artifacts
//! needed. These are the "proptest on coordinator invariants" deliverable:
//! routing (assignment), state bookkeeping (α/β), and estimator identities
//! hold for arbitrary shapes and data, not just the unit-test fixtures.

use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::propx::{assert_prop, FnGen, PropOpts};
use pamm::rngx::Xoshiro256;
use pamm::tensor::Mat;

/// Random (A, B, gen_idx) triple; sizes scale with the shrink parameter.
struct Case {
    a: Mat,
    b: Mat,
    idx: Vec<usize>,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case(a={}x{}, m={}, k={})",
            self.a.rows(),
            self.a.cols(),
            self.b.cols(),
            self.idx.len()
        )
    }
}

fn case_gen() -> impl pamm::propx::Gen<Item = Case> {
    FnGen(|rng: &mut Xoshiro256, size: usize| {
        let b = 4 + rng.next_below((4 * size.max(1)) as u64) as usize;
        let n = 2 + rng.next_below(size.max(2) as u64) as usize;
        let m = 2 + rng.next_below(size.max(2) as u64) as usize;
        let k = 1 + rng.next_below(b.min(size.max(1)) as u64) as usize;
        let a = Mat::random_normal(b, n, 1.0, rng);
        let bm = Mat::random_normal(b, m, 1.0, rng);
        let idx = pammc::sample_generators(rng, b, k);
        Case { a, b: bm, idx }
    })
}

#[test]
fn assignment_always_in_range_and_alpha_finite() {
    assert_prop(
        "assignment_in_range",
        &PropOpts { cases: 48, seed: 0xA1, max_size: 48 },
        &case_gen(),
        |c: &Case| {
            let comp = pammc::compress(&c.a, &c.idx, Eps::Inf);
            for (i, &f) in comp.assign.iter().enumerate() {
                if f as usize >= c.idx.len() {
                    return Err(format!("row {i}: f={f} out of range k={}", c.idx.len()));
                }
            }
            if !comp.alpha.iter().all(|a| a.is_finite()) {
                return Err("non-finite alpha".into());
            }
            if !(comp.beta.is_finite() && comp.beta >= 1.0 - 1e-6) {
                return Err(format!("beta {}", comp.beta));
            }
            Ok(())
        },
    );
}

#[test]
fn generators_represent_themselves_with_alpha_one() {
    assert_prop(
        "self_representation",
        &PropOpts { cases: 48, seed: 0xA2, max_size: 40 },
        &case_gen(),
        |c: &Case| {
            let comp = pammc::compress(&c.a, &c.idx, Eps::Inf);
            for (pos, &g) in c.idx.iter().enumerate() {
                // The generator row's best match must reconstruct itself:
                // α·C_f must equal the row (any collinear generator works).
                let al = comp.alpha[g];
                let f = comp.assign[g] as usize;
                let row = c.a.row(g);
                let cf = comp.generators.row(f);
                let err: f32 = (0..row.len())
                    .map(|j| (row[j] - al * cf[j]).powi(2))
                    .sum::<f32>()
                    .sqrt();
                let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
                if err > 1e-2 * norm.max(1e-3) {
                    return Err(format!(
                        "generator {pos} (row {g}) self-error {err} (norm {norm})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn beta_equals_b_over_kept() {
    assert_prop(
        "beta_bookkeeping",
        &PropOpts { cases: 48, seed: 0xA3, max_size: 48 },
        &case_gen(),
        |c: &Case| {
            for eps in [Eps::Val(0.0), Eps::Val(0.5), Eps::Inf] {
                let comp = pammc::compress(&c.a, &c.idx, eps);
                let kept = comp.alpha.iter().filter(|a| **a != 0.0).count();
                let expect = if kept > 0 { c.a.rows() as f32 / kept as f32 } else { 1.0 };
                if (comp.beta - expect).abs() > 1e-4 {
                    return Err(format!("beta {} != b/kept {expect} ({eps:?})", comp.beta));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn eps_inf_apply_equals_reconstruct_then_multiply() {
    assert_prop(
        "apply_identity",
        &PropOpts { cases: 32, seed: 0xA4, max_size: 32 },
        &case_gen(),
        |c: &Case| {
            let comp = pammc::compress(&c.a, &c.idx, Eps::Inf);
            let fast = pammc::apply(&comp, &c.b);
            let mut slow = comp.reconstruct().t_matmul(&c.b);
            slow.scale(comp.beta);
            let d = fast.max_abs_diff(&slow);
            let scale = slow.frob_norm().max(1.0);
            if d > 1e-3 * scale {
                return Err(format!("apply identity diff {d} (scale {scale})"));
            }
            Ok(())
        },
    );
}

/// Tentpole invariant: the parallel decompositions never change a bit of
/// the output. For arbitrary shapes, compress / apply / matmul at 2 and
/// 4 threads must equal the 1-thread result exactly (not within a
/// tolerance) — generators, assignment, alpha, beta, and every f32 of
/// the product matrices.
#[test]
fn parallel_results_bit_identical_across_1_2_4_threads() {
    assert_prop(
        "parallel_parity",
        &PropOpts { cases: 24, seed: 0xA7, max_size: 40 },
        &case_gen(),
        |c: &Case| {
            let serial = Pool::serial();
            let comp0 = pammc::compress_with(&c.a, &c.idx, Eps::Inf, &serial);
            let dw0 = pammc::apply_with(&comp0, &c.b, &serial);
            let exact0 = pammc::exact_matmul_with(&c.a, &c.b, &serial);
            let gt = comp0.generators.transpose();
            let mm0 = c.a.matmul_with(&gt, &serial);
            for threads in [2usize, 4] {
                // min_chunk 1 forces real splits at property-test sizes.
                let pool = Pool::new(threads).with_min_chunk(1);
                let comp = pammc::compress_with(&c.a, &c.idx, Eps::Inf, &pool);
                if comp.assign != comp0.assign {
                    return Err(format!("assign differs at t={threads}"));
                }
                if comp.alpha != comp0.alpha {
                    return Err(format!("alpha differs at t={threads}"));
                }
                if comp.beta.to_bits() != comp0.beta.to_bits() {
                    return Err(format!(
                        "beta {} != {} at t={threads}",
                        comp.beta, comp0.beta
                    ));
                }
                if comp.generators != comp0.generators {
                    return Err(format!("generators differ at t={threads}"));
                }
                if pammc::apply_with(&comp, &c.b, &pool) != dw0 {
                    return Err(format!("apply differs at t={threads}"));
                }
                if pammc::exact_matmul_with(&c.a, &c.b, &pool) != exact0 {
                    return Err(format!("exact_matmul differs at t={threads}"));
                }
                if c.a.matmul_with(&gt, &pool) != mm0 {
                    return Err(format!("matmul differs at t={threads}"));
                }
            }
            Ok(())
        },
    );
}

/// The serial-fallback threshold: a pool whose min_chunk exceeds the
/// input never splits, and the `_with` kernels still agree with the
/// plain serial entry points.
#[test]
fn serial_fallback_below_threshold_is_exact() {
    let pool = Pool::new(4).with_min_chunk(1 << 20);
    let mut rng = Xoshiro256::new(0xA8);
    let a = Mat::random_normal(33, 9, 1.0, &mut rng);
    let bm = Mat::random_normal(33, 5, 1.0, &mut rng);
    assert_eq!(pool.chunks_for(33), 1, "threshold must force one chunk");
    let idx = pammc::sample_generators(&mut rng, 33, 4);
    let comp_pool = pammc::compress_with(&a, &idx, Eps::Inf, &pool);
    let comp_serial = pammc::compress_with(&a, &idx, Eps::Inf, &Pool::serial());
    assert_eq!(comp_pool.assign, comp_serial.assign);
    assert_eq!(comp_pool.alpha, comp_serial.alpha);
    assert_eq!(
        pammc::apply_with(&comp_pool, &bm, &pool),
        pammc::apply_with(&comp_serial, &bm, &Pool::serial())
    );
    assert_eq!(a.matmul_tn_with(&bm, &pool), a.t_matmul(&bm));
}

#[test]
fn coverage_monotone_in_eps_property() {
    assert_prop(
        "coverage_monotone",
        &PropOpts { cases: 32, seed: 0xA5, max_size: 48 },
        &case_gen(),
        |c: &Case| {
            let cov = |e| pammc::compress(&c.a, &c.idx, e).coverage();
            let c0 = cov(Eps::Val(0.0));
            let c5 = cov(Eps::Val(0.5));
            let ci = cov(Eps::Inf);
            if !(c0 <= c5 + 1e-12 && c5 <= ci + 1e-12) {
                return Err(format!("coverage not monotone: {c0} {c5} {ci}"));
            }
            if (ci - 1.0).abs() > 1e-12 {
                return Err(format!("eps=inf coverage {ci} != 1"));
            }
            Ok(())
        },
    );
}

#[test]
fn full_generator_set_recovers_exact_product() {
    assert_prop(
        "exact_at_k_eq_b",
        &PropOpts { cases: 24, seed: 0xA6, max_size: 24 },
        &case_gen(),
        |c: &Case| {
            let idx: Vec<usize> = (0..c.a.rows()).collect();
            let approx = pammc::pamm_matmul(&c.a, &c.b, &idx, Eps::Inf);
            let exact = pammc::exact_matmul(&c.a, &c.b);
            let d = approx.max_abs_diff(&exact);
            let scale = exact.frob_norm().max(1.0);
            if d > 5e-3 * scale {
                return Err(format!("not exact at k=b: {d} (scale {scale})"));
            }
            Ok(())
        },
    );
}
