//! Property tests for the `data` pipeline — the modules `pamm train
//! --native` put on the hot path: tokenizer round-trip fidelity on
//! corpus text and `BatchIterator` seed determinism (the property the
//! checkpoint-resume fast-forward of `coordinator::lm` relies on).

use pamm::data::batcher::BatchIterator;
use pamm::data::corpus::{CorpusConfig, CorpusGenerator};
use pamm::data::tokenizer::{Tokenizer, PAD, SPECIAL_TOKENS};

fn corpus_doc(seed: u64, words: usize) -> String {
    let mut g = CorpusGenerator::new(CorpusConfig::default(), seed);
    g.document(words)
}

#[test]
fn encode_decode_round_trips_on_corpus_samples() {
    // Train once on one sample, then round-trip OTHER documents from
    // different corpus streams — the tokenizer must be lossless on the
    // language it will batch for training, not just its training text.
    let tok = Tokenizer::train(&corpus_doc(42, 3000), 512);
    for seed in [7u64, 99, 1234] {
        let doc = corpus_doc(seed, 400);
        let ids = tok.encode(&doc);
        assert_eq!(tok.decode(&ids), doc, "seed {seed}: decode(encode(x)) != x");
        assert!(ids.iter().all(|&t| t >= 0 && (t as usize) < tok.vocab_size()));
    }
}

#[test]
fn tokenizer_training_is_deterministic_across_instances() {
    let sample = corpus_doc(42, 2000);
    let a = Tokenizer::train(&sample, 400);
    let b = Tokenizer::train(&sample, 400);
    let probe = corpus_doc(5, 300);
    assert_eq!(a.encode(&probe), b.encode(&probe));
    assert_eq!(a.vocab_size(), b.vocab_size());
}

#[test]
fn batch_iterator_same_seed_same_stream() {
    // Two independently constructed iterators (each trains its own
    // tokenizer) must produce identical token streams for one seed —
    // this is what makes a training run reproducible from its seed.
    let mut a = BatchIterator::from_seed(512, 4, 32, 11);
    let mut b = BatchIterator::from_seed(512, 4, 32, 11);
    for step in 0..8 {
        assert_eq!(a.next_batch().tokens, b.next_batch().tokens, "step {step}");
    }
}

#[test]
fn batch_iterator_different_seeds_differ() {
    let mut a = BatchIterator::from_seed(512, 2, 32, 1);
    let mut b = BatchIterator::from_seed(512, 2, 32, 2);
    // Same vocabulary (the tokenizer sample seed is fixed), different
    // document streams.
    assert_eq!(a.tokenizer().vocab_size(), b.tokenizer().vocab_size());
    let mut any_diff = false;
    for _ in 0..4 {
        if a.next_batch().tokens != b.next_batch().tokens {
            any_diff = true;
        }
    }
    assert!(any_diff, "different seeds must yield different token streams");
}

#[test]
fn skip_batches_equals_draining() {
    // skip_batches(n) + next == (n+1) next_batch calls — the resume
    // fast-forward contract.
    let mut skipped = BatchIterator::from_seed(512, 2, 24, 21);
    let mut drained = BatchIterator::from_seed(512, 2, 24, 21);
    skipped.skip_batches(5);
    for _ in 0..5 {
        let _ = drained.next_batch();
    }
    for step in 0..3 {
        assert_eq!(skipped.next_batch().tokens, drained.next_batch().tokens, "step {step}");
    }
}

#[test]
fn packed_batches_are_lm_ready() {
    // (batch, seq+1) rows, no padding, every id in range, and the
    // input/target overlap convention holds: row[1..] is row shifted.
    let (batch, seq) = (3usize, 40usize);
    let mut it = BatchIterator::from_seed(512, batch, seq, 31);
    for _ in 0..3 {
        let b = it.next_batch();
        assert_eq!(b.tokens.len(), batch * (seq + 1));
        assert_eq!(b.n_tokens(), batch * seq);
        let cap = it.tokenizer().vocab_size() as i32;
        assert!(b.tokens.iter().all(|&t| t >= 0 && t < cap));
        assert!(b.tokens.iter().filter(|&&t| t == PAD).count() == 0, "dense packing, no PAD");
        // Sanity on the special-token floor: real text tokens dominate.
        let specials =
            b.tokens.iter().filter(|&&t| (t as usize) < SPECIAL_TOKENS).count();
        assert!(specials * 4 < b.tokens.len(), "specials {specials} of {}", b.tokens.len());
    }
}
