//! Property tests for the `data` pipeline — the modules `pamm train
//! --native` put on the hot path: tokenizer round-trip fidelity on
//! corpus text and `BatchIterator` seed determinism (the property the
//! checkpoint-resume fast-forward of `coordinator::lm` relies on).

use pamm::data::batcher::BatchIterator;
use pamm::data::corpus::{CorpusConfig, CorpusGenerator};
use pamm::data::glue::{glue_suite, LabeledStream, TaskCorpus, TaskSpec};
use pamm::data::tokenizer::{Tokenizer, PAD, SPECIAL_TOKENS};

fn corpus_doc(seed: u64, words: usize) -> String {
    let mut g = CorpusGenerator::new(CorpusConfig::default(), seed);
    g.document(words)
}

#[test]
fn encode_decode_round_trips_on_corpus_samples() {
    // Train once on one sample, then round-trip OTHER documents from
    // different corpus streams — the tokenizer must be lossless on the
    // language it will batch for training, not just its training text.
    let tok = Tokenizer::train(&corpus_doc(42, 3000), 512);
    for seed in [7u64, 99, 1234] {
        let doc = corpus_doc(seed, 400);
        let ids = tok.encode(&doc);
        assert_eq!(tok.decode(&ids), doc, "seed {seed}: decode(encode(x)) != x");
        assert!(ids.iter().all(|&t| t >= 0 && (t as usize) < tok.vocab_size()));
    }
}

#[test]
fn tokenizer_training_is_deterministic_across_instances() {
    let sample = corpus_doc(42, 2000);
    let a = Tokenizer::train(&sample, 400);
    let b = Tokenizer::train(&sample, 400);
    let probe = corpus_doc(5, 300);
    assert_eq!(a.encode(&probe), b.encode(&probe));
    assert_eq!(a.vocab_size(), b.vocab_size());
}

#[test]
fn batch_iterator_same_seed_same_stream() {
    // Two independently constructed iterators (each trains its own
    // tokenizer) must produce identical token streams for one seed —
    // this is what makes a training run reproducible from its seed.
    let mut a = BatchIterator::from_seed(512, 4, 32, 11);
    let mut b = BatchIterator::from_seed(512, 4, 32, 11);
    for step in 0..8 {
        assert_eq!(a.next_batch().tokens, b.next_batch().tokens, "step {step}");
    }
}

#[test]
fn batch_iterator_different_seeds_differ() {
    let mut a = BatchIterator::from_seed(512, 2, 32, 1);
    let mut b = BatchIterator::from_seed(512, 2, 32, 2);
    // Same vocabulary (the tokenizer sample seed is fixed), different
    // document streams.
    assert_eq!(a.tokenizer().vocab_size(), b.tokenizer().vocab_size());
    let mut any_diff = false;
    for _ in 0..4 {
        if a.next_batch().tokens != b.next_batch().tokens {
            any_diff = true;
        }
    }
    assert!(any_diff, "different seeds must yield different token streams");
}

#[test]
fn skip_batches_equals_draining() {
    // skip_batches(n) + next == (n+1) next_batch calls — the resume
    // fast-forward contract.
    let mut skipped = BatchIterator::from_seed(512, 2, 24, 21);
    let mut drained = BatchIterator::from_seed(512, 2, 24, 21);
    skipped.skip_batches(5);
    for _ in 0..5 {
        let _ = drained.next_batch();
    }
    for step in 0..3 {
        assert_eq!(skipped.next_batch().tokens, drained.next_batch().tokens, "step {step}");
    }
}

#[test]
fn packed_batches_are_lm_ready() {
    // (batch, seq+1) rows, no padding, every id in range, and the
    // input/target overlap convention holds: row[1..] is row shifted.
    let (batch, seq) = (3usize, 40usize);
    let mut it = BatchIterator::from_seed(512, batch, seq, 31);
    for _ in 0..3 {
        let b = it.next_batch();
        assert_eq!(b.tokens.len(), batch * (seq + 1));
        assert_eq!(b.n_tokens(), batch * seq);
        let cap = it.tokenizer().vocab_size() as i32;
        assert!(b.tokens.iter().all(|&t| t >= 0 && t < cap));
        assert!(b.tokens.iter().filter(|&&t| t == PAD).count() == 0, "dense packing, no PAD");
        // Sanity on the special-token floor: real text tokens dominate.
        let specials =
            b.tokens.iter().filter(|&&t| (t as usize) < SPECIAL_TOKENS).count();
        assert!(specials * 4 < b.tokens.len(), "specials {specials} of {}", b.tokens.len());
    }
}

// ---------------------------------------------------------------------------
// GLUE-style labeled corpora (`data::glue` — the `pamm finetune
// --native` input path)
// ---------------------------------------------------------------------------

fn glue_spec(name: &str) -> TaskSpec {
    glue_suite().into_iter().find(|s| s.name == name).expect("known GLUE task")
}

#[test]
fn glue_synthetic_corpus_is_deterministic() {
    let spec = glue_spec("SST2");
    let (vocab, seq, n) = (300usize, 16usize, 24usize);
    let a = TaskCorpus::synthetic(spec.clone(), vocab, seq, n, 7);
    let b = TaskCorpus::synthetic(spec.clone(), vocab, seq, n, 7);
    assert_eq!(a.examples.len(), n);
    for (i, (ea, eb)) in a.examples.iter().zip(&b.examples).enumerate() {
        assert_eq!(ea.tokens, eb.tokens, "example {i}: tokens");
        assert_eq!(ea.label, eb.label, "example {i}: label");
    }
    // A different seed must change the example universe somewhere.
    let c = TaskCorpus::synthetic(spec, vocab, seq, n, 8);
    assert!(
        a.examples.iter().zip(&c.examples).any(|(ea, ec)| ea.tokens != ec.tokens),
        "seed must matter"
    );
    // Labels must span every class (the generator is class-balanced
    // enough for 24 examples over 2 classes).
    for cls in 0..a.spec.n_classes as i32 {
        assert!(a.examples.iter().any(|e| e.label == cls), "class {cls} unrepresented");
    }
}

#[test]
fn glue_labels_round_trip_through_the_labeled_stream() {
    // Every packed row the stream emits must be a corpus example —
    // tokens AND label together — and within one epoch no example may
    // be emitted twice (the epoch permutation is a draw without
    // replacement over full batches).
    let spec = glue_spec("MNLI"); // 3 classes — labels are non-binary
    let (vocab, seq, n, batch) = (300usize, 12usize, 22usize, 4usize);
    let corpus = TaskCorpus::synthetic(spec, vocab, seq, n, 13);
    let examples = corpus.examples.clone();
    let mut stream = LabeledStream::new(corpus, batch, 13);
    let bpe = stream.batches_per_epoch();
    assert_eq!(bpe, n / batch, "full batches only — the ragged tail is dropped");
    let mut used = vec![false; examples.len()];
    for b in 0..bpe {
        let lb = stream.next_batch();
        assert_eq!(lb.batch, batch);
        assert_eq!(lb.seq, seq);
        assert_eq!(lb.tokens.len(), batch * seq);
        assert_eq!(lb.labels.len(), batch);
        for r in 0..batch {
            let row = &lb.tokens[r * seq..(r + 1) * seq];
            let hit = examples
                .iter()
                .enumerate()
                .position(|(i, e)| !used[i] && e.tokens == row && e.label == lb.labels[r]);
            let i = hit.unwrap_or_else(|| {
                panic!("batch {b} row {r}: not an unused corpus example (label {})", lb.labels[r])
            });
            used[i] = true;
        }
    }
    assert_eq!(used.iter().filter(|&&u| u).count(), bpe * batch);
}

#[test]
fn glue_split_is_disjoint_and_complete() {
    let spec = glue_spec("RTE");
    let (vocab, seq, n, dev_every) = (300usize, 10usize, 23usize, 4usize);
    let full = TaskCorpus::synthetic(spec, vocab, seq, n, 19);
    let originals = full.examples.clone();
    let (train, dev) = full.split(dev_every);
    // The stride rule: index i goes to dev iff i % dev_every == dev_every-1.
    let want_dev = originals.iter().enumerate().filter(|(i, _)| i % dev_every == dev_every - 1);
    let want_train =
        originals.iter().enumerate().filter(|(i, _)| i % dev_every != dev_every - 1);
    assert_eq!(train.examples.len() + dev.examples.len(), n, "no example lost or duplicated");
    for ((_, want), got) in want_dev.zip(&dev.examples) {
        assert_eq!(want.tokens, got.tokens);
        assert_eq!(want.label, got.label);
    }
    for ((_, want), got) in want_train.zip(&train.examples) {
        assert_eq!(want.tokens, got.tokens);
        assert_eq!(want.label, got.label);
    }
    // No leakage: no dev row appears among the train rows.
    for (di, d) in dev.examples.iter().enumerate() {
        assert!(
            !train.examples.iter().any(|t| t.tokens == d.tokens),
            "dev example {di} leaked into the train split"
        );
    }
}

#[test]
fn glue_ragged_tail_and_skip_contract_match_batcher_semantics() {
    // eval_batches and the stream agree with `BatchIterator`'s shard
    // semantics: len/batch full batches, fixed order for eval, and
    // skip_batches(n) ≡ draining n batches (the resume fast-forward).
    let spec = glue_spec("SST2");
    let (vocab, seq, n, batch) = (300usize, 8usize, 10usize, 4usize);
    let corpus = TaskCorpus::synthetic(spec, vocab, seq, n, 23);
    let evals = corpus.eval_batches(batch);
    assert_eq!(evals.len(), n / batch, "eval drops the ragged tail");
    for (b, lb) in evals.iter().enumerate() {
        for r in 0..batch {
            let e = &corpus.examples[b * batch + r];
            assert_eq!(lb.tokens[r * seq..(r + 1) * seq], e.tokens[..], "eval order is fixed");
            assert_eq!(lb.labels[r], e.label);
        }
    }
    let mut skipped = LabeledStream::new(corpus.clone(), batch, 31);
    let mut drained = LabeledStream::new(corpus, batch, 31);
    skipped.skip_batches(3); // crosses an epoch boundary at bpe == 2
    for _ in 0..3 {
        let _ = drained.next_batch();
    }
    for step in 0..4 {
        let a = skipped.next_batch();
        let b = drained.next_batch();
        assert_eq!(a.tokens, b.tokens, "step {step}: tokens");
        assert_eq!(a.labels, b.labels, "step {step}: labels");
    }
}
