//! Property tests for the attention subsystem: f64 naive-attention
//! oracle on ragged tile boundaries (seq = Br±1, Bc±1 and a multi-tile
//! shape), scalar==sse2==avx2 bit-equality, 1/2/4-thread parity,
//! fused-vs-materialize equivalence, and the measured peak-memory
//! acceptance bound of `attention::pamm_qkv_attention`.
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does) — the `*_with` assertions then cover both global dispatch
//! modes, while the explicit-dispatch assertions sweep the whole ladder
//! in a single process regardless of the env var.

use pamm::attention::{self, AttnShape, AttnTiles, BC, BR};
use pamm::memory::MemoryTracker;
use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::poolx::Pool;
use pamm::rngx::Xoshiro256;
use pamm::tensor::kernels::{self, Dispatch};
use pamm::tensor::Mat;

fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    let mut v = vec![0f32; len];
    rng.fill_normal_f32(&mut v, 1.0);
    v
}

fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    Mat::random_normal(rows, cols, 1.0, &mut rng)
}

/// Independent f64 reference: materialized scores, exact masked
/// softmax, f64 accumulation throughout. Deliberately NOT the module's
/// own `naive_attention` (that one is f32 and shares the −1e30 mask
/// idiom) so the oracle cannot inherit a bug from the implementation.
fn oracle(q: &[f32], k: &[f32], v: &[f32], shape: &AttnShape) -> Vec<f32> {
    let (l, d) = (shape.seq, shape.head_dim);
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = vec![0f32; shape.qkv_len()];
    for t in 0..shape.batch * shape.heads {
        let off = t * l * d;
        for i in 0..l {
            let qi = &q[off + i * d..off + (i + 1) * d];
            let jmax = if shape.causal { i + 1 } else { l };
            let mut scores = vec![0f64; jmax];
            for (j, s) in scores.iter_mut().enumerate() {
                let kj = &k[off + j * d..off + (j + 1) * d];
                *s = scale
                    * qi.iter().zip(kj).map(|(a, b)| *a as f64 * *b as f64).sum::<f64>();
            }
            let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut sum = 0f64;
            for s in scores.iter_mut() {
                *s = (*s - mx).exp();
                sum += *s;
            }
            let orow = &mut out[off + i * d..off + (i + 1) * d];
            for c in 0..d {
                let mut acc = 0f64;
                for (j, p) in scores.iter().enumerate() {
                    acc += p * v[off + j * d + c] as f64;
                }
                orow[c] = (acc / sum) as f32;
            }
        }
    }
    out
}

/// Ragged tile boundaries around the Br/Bc blocking, plus degenerate
/// and multi-tile sequence lengths. head_dim alternates between an
/// NR-aligned and a ragged width so the packed-panel edges get hit too.
fn edge_shapes() -> Vec<AttnShape> {
    let seqs = [1usize, 7, BR - 1, BR, BR + 1, BC + 1, 2 * BC + 3];
    let mut shapes = Vec::new();
    for (ix, &l) in seqs.iter().enumerate() {
        let d = if ix % 2 == 0 { 8 } else { 17 };
        for causal in [false, true] {
            shapes.push(AttnShape::new(1 + ix % 2, 1 + (ix + 1) % 2, l, d, causal));
        }
    }
    shapes
}

#[test]
fn flash_matches_f64_oracle_on_ragged_shapes() {
    let serial = Pool::serial();
    for (ix, shape) in edge_shapes().iter().enumerate() {
        let n = shape.qkv_len();
        let q = rand_vec(n, 100 + ix as u64);
        let k = rand_vec(n, 200 + ix as u64);
        let v = rand_vec(n, 300 + ix as u64);
        let want = oracle(&q, &k, &v, shape);
        let got = attention::flash_attention_with(&q, &k, &v, shape, &serial);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "{shape:?} elem {i}: {g} vs {w}"
            );
        }
    }
}

#[test]
fn every_dispatch_level_is_bit_identical_on_every_edge_shape() {
    let serial = Pool::serial();
    for (ix, shape) in edge_shapes().iter().enumerate() {
        let n = shape.qkv_len();
        let q = rand_vec(n, 400 + ix as u64);
        let k = rand_vec(n, 500 + ix as u64);
        let v = rand_vec(n, 600 + ix as u64);
        let base = attention::flash_attention_on(Dispatch::Scalar, &q, &k, &v, shape, &serial);
        for d in [Dispatch::Sse2, Dispatch::Avx2, Dispatch::native()] {
            if !d.available() {
                continue;
            }
            let got = attention::flash_attention_on(d, &q, &k, &v, shape, &serial);
            for (i, (g, w)) in got.iter().zip(&base).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{} vs scalar: {shape:?} elem {i}",
                    d.name()
                );
            }
        }
    }
}

#[test]
fn fast_tier_flash_stays_within_the_tolerance_oracle() {
    // The FMA tier must agree with the independent f64 oracle at the
    // same bar as the ladder AND with the scalar flash walk within the
    // relative-tolerance oracle (depth ≈ seq softmax chain + head_dim
    // GEMM chain) — on the same ragged Br/Bc boundaries.
    let serial = Pool::serial();
    for (ix, shape) in edge_shapes().iter().enumerate() {
        let n = shape.qkv_len();
        let q = rand_vec(n, 1000 + ix as u64);
        let k = rand_vec(n, 1100 + ix as u64);
        let v = rand_vec(n, 1200 + ix as u64);
        let want = oracle(&q, &k, &v, shape);
        let base = attention::flash_attention_on(Dispatch::Scalar, &q, &k, &v, shape, &serial);
        for d in kernels::FAST_TIER {
            if !d.available() {
                continue;
            }
            let got = attention::flash_attention_on(d, &q, &k, &v, shape, &serial);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                    "{} {shape:?} elem {i}: {g} vs f64 {w}",
                    d.name()
                );
            }
            kernels::tol_check(&got, &base, shape.seq + shape.head_dim)
                .unwrap_or_else(|e| panic!("{} {shape:?}: {e}", d.name()));
        }
    }
}

#[test]
fn autotuned_attention_tiles_stay_within_the_tolerance_oracle() {
    // Non-default Br/Bc (the kind `--tune` installs) regroup the online
    // softmax update order — bit-relevant, but every configuration must
    // stay within the same relative tolerance of the default tiling, at
    // the bit-exact native level and the fast tier alike.
    let serial = Pool::serial();
    let tile_sets =
        [AttnTiles { br: 16, bc: 16 }, AttnTiles { br: 32, bc: 128 }, AttnTiles { br: 96, bc: 48 }];
    for (ix, shape) in edge_shapes().iter().enumerate() {
        let n = shape.qkv_len();
        let q = rand_vec(n, 1300 + ix as u64);
        let k = rand_vec(n, 1400 + ix as u64);
        let v = rand_vec(n, 1500 + ix as u64);
        let base = attention::flash_attention_tiled(
            Dispatch::Scalar,
            &q,
            &k,
            &v,
            shape,
            &serial,
            AttnTiles::defaults(),
        );
        for d in [Dispatch::native(), Dispatch::fastest()] {
            for t in tile_sets {
                let got = attention::flash_attention_tiled(d, &q, &k, &v, shape, &serial, t);
                kernels::tol_check(&got, &base, shape.seq + shape.head_dim)
                    .unwrap_or_else(|e| panic!("{} tiles {t:?} {shape:?}: {e}", d.name()));
            }
        }
    }
}

#[test]
fn thread_count_is_bit_invariant() {
    // Enough (batch·head) tasks that 4 threads genuinely split the grid.
    for shape in [
        AttnShape::new(2, 2, BR + 5, 16, true),
        AttnShape::new(2, 4, BC - 1, 17, false),
    ] {
        let n = shape.qkv_len();
        let q = rand_vec(n, 700);
        let k = rand_vec(n, 701);
        let v = rand_vec(n, 702);
        let serial = attention::flash_attention_with(&q, &k, &v, &shape, &Pool::serial());
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            let got = attention::flash_attention_with(&q, &k, &v, &shape, &pool);
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                serial.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{shape:?} t={threads}"
            );
        }
    }
}

#[test]
fn fused_is_bit_invariant_across_threads_and_levels() {
    let shape = AttnShape::new(2, 2, BR + 3, 16, true);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 800);
    let wq = rand_mat(dm, dm, 801);
    let wk = rand_mat(dm, dm, 802);
    let wv = rand_mat(dm, dm, 803);
    let mut rng = Xoshiro256::new(804);
    let idx = pammc::sample_generators(&mut rng, shape.tokens(), 20);
    let comp = pammc::compress(&x, &idx, Eps::Inf);

    let serial = Pool::serial();
    let base = attention::attend_compressed_on(
        Dispatch::Scalar, &comp, &wq, &wk, &wv, &shape, &serial, None,
    );
    for d in [Dispatch::Sse2, Dispatch::Avx2] {
        if !d.available() {
            continue;
        }
        let got =
            attention::attend_compressed_on(d, &comp, &wq, &wk, &wv, &shape, &serial, None);
        for (i, (g, w)) in got.iter().zip(&base).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "fused {} vs scalar elem {i}", d.name());
        }
    }
    for threads in [2usize, 4] {
        let pool = Pool::new(threads);
        let got = attention::attend_compressed_on(
            Dispatch::Scalar, &comp, &wq, &wk, &wv, &shape, &pool, None,
        );
        for (i, (g, w)) in got.iter().zip(&base).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "fused t={threads} elem {i}");
        }
    }
}

#[test]
fn fused_matches_materialize_then_attend_within_lemma1_rounding() {
    // Same math, different association: fused computes α·(C·W) rows,
    // the materialized path (diag(α)·C)·W — agreement up to GEMM
    // rounding, for both ε = ∞ (all rows kept) and a tight ε with
    // dropped rows.
    for (seed, eps) in [(900u64, Eps::Inf), (910, Eps::Val(0.6))] {
        let shape = AttnShape::new(2, 2, 45, 8, true);
        let dm = shape.d_model();
        let x = rand_mat(shape.tokens(), dm, seed);
        let wq = rand_mat(dm, dm, seed + 1);
        let wk = rand_mat(dm, dm, seed + 2);
        let wv = rand_mat(dm, dm, seed + 3);
        let mut rng = Xoshiro256::new(seed + 4);
        let idx = pammc::sample_generators(&mut rng, shape.tokens(), 14);
        let pool = Pool::serial();
        let (comp, fused) =
            attention::pamm_qkv_attention_with(&x, &wq, &wk, &wv, &idx, eps, &shape, &pool);
        let xr = comp.reconstruct();
        let q = attention::split_heads(&xr.matmul(&wq), &shape);
        let k = attention::split_heads(&xr.matmul(&wk), &shape);
        let v = attention::split_heads(&xr.matmul(&wv), &shape);
        let want = attention::flash_attention_with(&q, &k, &v, &shape, &pool);
        for (i, (g, w)) in fused.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "eps={eps:?} elem {i}: fused {g} vs materialized {w}"
            );
        }
    }
}

#[test]
fn all_generators_fused_recovers_exact_attention() {
    // With every row a generator, Ã = A exactly (Lemma 1's zero-error
    // case), so the fused path must agree with dense attention from x.
    let shape = AttnShape::new(1, 2, 30, 8, false);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 920);
    let wq = rand_mat(dm, dm, 921);
    let wk = rand_mat(dm, dm, 922);
    let wv = rand_mat(dm, dm, 923);
    let idx: Vec<usize> = (0..shape.tokens()).collect();
    let pool = Pool::serial();
    let (_, fused) =
        attention::pamm_qkv_attention_with(&x, &wq, &wk, &wv, &idx, Eps::Inf, &shape, &pool);
    let q = attention::split_heads(&x.matmul(&wq), &shape);
    let k = attention::split_heads(&x.matmul(&wk), &shape);
    let v = attention::split_heads(&x.matmul(&wv), &shape);
    let want = oracle(&q, &k, &v, &shape);
    for (i, (g, w)) in fused.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() <= 2e-3 * w.abs().max(1.0),
            "elem {i}: fused {g} vs exact {w}"
        );
    }
}

#[test]
fn fused_peak_memory_stays_below_the_bound_and_below_qkv() {
    // The acceptance invariant: peak tracked bytes of the fused path
    // stay under fused_peak_bound (tile scratch × threads + the
    // compressed-domain state + the caller's projection packing), and
    // far under one materialized Q/K/V set — measured, not modeled.
    let shape = AttnShape::new(2, 2, 256, 32, true);
    let dm = shape.d_model();
    let x = rand_mat(shape.tokens(), dm, 930);
    let wq = rand_mat(dm, dm, 931);
    let wk = rand_mat(dm, dm, 932);
    let wv = rand_mat(dm, dm, 933);
    let mut rng = Xoshiro256::new(934);
    let idx = pammc::sample_generators(&mut rng, shape.tokens(), 24);

    let threads = 2usize;
    let pool = Pool::new(threads); // fresh pool ⇒ cold worker TLS
    let tracker = MemoryTracker::new();
    let (comp, out) = attention::pamm_qkv_attention_tracked(
        &x,
        &wq,
        &wk,
        &wv,
        &idx,
        Eps::Inf,
        &shape,
        &pool,
        Some(&tracker),
    );
    assert_eq!(out.len(), shape.qkv_len());
    let peak = tracker.peak();
    assert!(peak > 0, "tracker saw no allocations");

    let bound = attention::fused_peak_bound(&comp, &shape, threads);
    assert!(peak <= bound, "measured peak {peak} exceeds fused_peak_bound {bound}");

    let qkv = 3 * shape.tensor_bytes();
    assert!(
        peak * 2 < qkv,
        "fused peak {peak} not meaningfully below the materialized Q/K/V set {qkv}"
    );
    // The bound itself (not just the measurement) undercuts QKV here.
    assert!(bound < qkv, "bound {bound} vs materialized {qkv}");
}
