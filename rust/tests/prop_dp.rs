//! Data-parallel training property tests (DESIGN.md §10,
//! EXPERIMENTS.md P16):
//!
//! * **Single-worker identity** — an R=1, A=1 fleet run is bitwise the
//!   single-process `train_lm_native` run: identical replayed loss
//!   curve and identical final parameters.
//! * **Stream partition** — the R interleaved [`BatchShard`]s consume
//!   exactly the global microbatch stream `j = s·E + r·A + a` of the
//!   plain [`BatchIterator`]: nothing duplicated, nothing reordered.
//! * **Factorization + thread invariance** — every `R × A` split of a
//!   fixed effective batch E, at every physical thread count, produces
//!   the identical loss trajectory and final merged parameters
//!   (gradient-accumulation equivalence falls out as the R=1 column).
//! * **Kill-anywhere bit-parity** — a supervised fleet killed at EVERY
//!   (rank × checkpoint boundary × crash phase) recovers from the
//!   sharded ring to a final checkpoint AND replayed run log bitwise
//!   identical to the uninterrupted fleet's.
//! * **Shard corruption fallback** — scripted bitrot in one shard of
//!   the newest sharded entry is detected per-shard (CRC), reported,
//!   and recovery falls back a whole entry — then still converges
//!   bitwise.
//! * **Elastic degradation determinism** — a straggler past the stall
//!   budget dies, the fleet reshards onto the survivor at the next
//!   boundary, and the degraded trajectory is reproducible bit for bit
//!   at any thread count (while the non-elastic run fails fast with an
//!   actionable diagnostic).
//!
//! Run under both `PAMM_SIMD=native` (default) and `PAMM_SIMD=scalar`
//! (CI does both).

use std::path::PathBuf;

use pamm::checkpoint;
use pamm::coordinator::dp::DpReshard;
use pamm::coordinator::{
    checkpoint_boundaries, train_lm_dp_native_run, train_lm_dp_supervised, train_lm_native_run,
    DpRunConfig, LmRunConfig, NativeOpt,
};
use pamm::data::{BatchIterator, BatchShard};
use pamm::faultx::{CrashPhase, FaultPlan};
use pamm::metrics::replay_run_log;
use pamm::model::LmConfig;
use pamm::poolx::Pool;
use pamm::runtime::HostTensor;

fn scratch(test: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pamm_prop_dp_{}_{test}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn base_rc(dir: &std::path::Path, run_name: &str, steps: usize) -> LmRunConfig {
    LmRunConfig {
        cfg: LmConfig { vocab: 120, n_layers: 2, heads: 2, head_dim: 8, d_ff: 32 },
        batch: 1,
        seq: 8,
        steps,
        k: 4,
        opt: NativeOpt::adam(3e-3),
        seed: 33,
        ckpt_every: 2,
        keep_last: 3,
        run_dir: dir.join(run_name).to_string_lossy().into_owned(),
        run_name: run_name.to_string(),
        resume: false,
    }
}

fn dp_rc(dir: &std::path::Path, run_name: &str, steps: usize, workers: usize, accum: usize) -> DpRunConfig {
    DpRunConfig {
        base: base_rc(dir, run_name, steps),
        workers,
        accum,
        elastic: false,
        stall_budget: 3,
    }
}

/// Final checkpoint restricted to model parameters: the single-process
/// final checkpoint also carries optimizer/meta tensors the merged DP
/// checkpoint deliberately omits, so cross-path comparisons use the
/// parameter set both formats share.
fn final_params(rc: &LmRunConfig) -> Vec<(String, HostTensor)> {
    checkpoint::load(format!("{}/ckpt", rc.run_dir), &rc.run_name)
        .expect("final checkpoint")
        .into_iter()
        .filter(|(n, _)| !n.starts_with("meta.") && !n.starts_with("opt_"))
        .collect()
}

fn replayed(rc: &LmRunConfig) -> Vec<(usize, u64)> {
    replay_run_log(&rc.run_dir, &rc.run_name)
        .expect("replay run log")
        .into_iter()
        .map(|(s, l)| (s, l.to_bits()))
        .collect()
}

#[test]
fn single_worker_fleet_bit_matches_the_single_process_trainer() {
    let dir = scratch("r1_identity");
    let pool = Pool::serial();
    let lm_rc = base_rc(&dir, "lm", 8);
    let lm_out = train_lm_native_run(&lm_rc, None, &pool, true).unwrap();

    let rc = dp_rc(&dir, "dp", 8, 1, 1);
    let dp_out = train_lm_dp_native_run(&rc, None, &[], &pool, true).unwrap();

    assert_eq!(
        lm_out.outcome.final_loss.to_bits(),
        dp_out.outcome.final_loss.to_bits(),
        "R=1 A=1 final loss must bit-match the single-process run"
    );
    assert_eq!(replayed(&lm_rc), replayed(&rc.base), "replayed loss curves must bit-match");
    assert_eq!(
        final_params(&lm_rc),
        final_params(&rc.base),
        "final parameters must bit-match"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shards_partition_the_global_microbatch_stream() {
    let (vocab, batch, seq, seed) = (50usize, 2usize, 6usize, 9u64);
    let (ranks, accum, rounds) = (3usize, 2usize, 4usize);
    let e = ranks * accum;

    let mut global = BatchIterator::from_seed(vocab, batch, seq, seed);
    let stream: Vec<Vec<i32>> = (0..e * rounds).map(|_| global.next_batch().tokens).collect();

    for r in 0..ranks {
        let mut shard = BatchShard::new(vocab, batch, seq, seed, r, ranks, accum);
        for s in 0..rounds {
            for a in 0..accum {
                let got = shard.next_batch().tokens;
                let j = s * e + r * accum + a;
                assert_eq!(
                    got, stream[j],
                    "rank {r} microbatch (round {s}, a {a}) must be global microbatch {j}"
                );
            }
        }
        assert_eq!(shard.cursor(), e * rounds + r * accum, "cursor sits at the next round's slot");
    }
}

#[test]
fn fixed_e_factorizations_and_thread_counts_agree() {
    let dir = scratch("factorizations");
    let steps = 4;
    let mut reference: Option<(Vec<(usize, u64)>, Vec<(String, HostTensor)>)> = None;
    for (workers, accum) in [(1usize, 4usize), (2, 2), (4, 1)] {
        for threads in [1usize, 2, 4] {
            let pool =
                if threads == 1 { Pool::serial() } else { Pool::new(threads).with_min_chunk(1) };
            let name = format!("w{workers}a{accum}t{threads}");
            let rc = dp_rc(&dir, &name, steps, workers, accum);
            train_lm_dp_native_run(&rc, None, &[], &pool, true)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            let got = (replayed(&rc.base), final_params(&rc.base));
            match &reference {
                None => reference = Some(got),
                Some(want) => {
                    assert_eq!(&got.0, &want.0, "{name}: loss trajectory drifted");
                    assert_eq!(&got.1, &want.1, "{name}: final parameters drifted");
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn worker_kill_recovery_is_bitwise_at_every_rank_boundary_and_phase() {
    let dir = scratch("kill_sweep");
    let pool = Pool::serial();
    let steps = 6;
    let workers = 2;
    let base = dp_rc(&dir, "base", steps, workers, 1);
    train_lm_dp_native_run(&base, None, &[], &pool, true).unwrap();
    let base_final = final_params(&base.base);
    let base_log = replayed(&base.base);
    let boundaries = checkpoint_boundaries(&base.base);
    assert_eq!(boundaries, vec![2, 4, 6]);

    let plans = FaultPlan::every_worker_boundary(33, workers, &boundaries);
    assert_eq!(plans.len(), workers * boundaries.len() * CrashPhase::ALL.len());
    for (i, plan) in plans.iter().enumerate() {
        let k = plan.worker_kills[0];
        let rc = dp_rc(&dir, &format!("kill_{i}"), steps, workers, 1);
        let out = train_lm_dp_supervised(&rc, plan, &pool, true)
            .unwrap_or_else(|e| panic!("kill r{} s{}/{}: {e:#}", k.rank, k.step, k.phase.name()));
        assert_eq!(out.kills.len(), 1, "kill r{} s{}/{} never fired", k.rank, k.step, k.phase.name());
        assert_eq!(out.attempts, 2, "one kill ⇒ exactly one recovery launch");
        assert_eq!(
            final_params(&rc.base),
            base_final,
            "kill r{} s{}/{}: final checkpoint drifted",
            k.rank,
            k.step,
            k.phase.name()
        );
        assert_eq!(
            replayed(&rc.base),
            base_log,
            "kill r{} s{}/{}: replayed run log drifted",
            k.rank,
            k.step,
            k.phase.name()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_shard_is_detected_and_recovery_falls_back_a_whole_entry() {
    let dir = scratch("shard_corruption");
    let pool = Pool::serial();
    let steps = 6;
    let base = dp_rc(&dir, "base", steps, 2, 1);
    train_lm_dp_native_run(&base, None, &[], &pool, true).unwrap();
    let base_final = final_params(&base.base);

    // Kill right after the step-4 sharded entry committed, then flip
    // one seeded bit in one of its shards: recovery must flag that
    // shard, discard the whole entry, and resume from step 2.
    let rc = dp_rc(&dir, "corrupt", steps, 2, 1);
    let plan = FaultPlan::new(33)
        .with_worker_kill(1, 4, CrashPhase::AfterCheckpoint)
        .with_corruption(0);
    let out = train_lm_dp_supervised(&rc, &plan, &pool, true).unwrap();
    assert!(
        out.recovery_diags.iter().any(|d| d.contains("injected corruption")),
        "corruption injection missing from diags: {:?}",
        out.recovery_diags
    );
    assert!(
        out.recovery_diags
            .iter()
            .any(|d| d.contains("shard") && d.contains("failed verification")),
        "per-shard CRC never flagged the flipped shard: {:?}",
        out.recovery_diags
    );
    assert_eq!(out.resume_steps, vec![2], "must fall back past the corrupt step-4 entry");
    assert_eq!(final_params(&rc.base), base_final, "post-fallback run drifted from baseline");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn straggler_timeout_fails_fast_without_elastic() {
    let dir = scratch("timeout");
    let rc = dp_rc(&dir, "timeout", 6, 2, 1);
    let plan = FaultPlan::new(33).with_stall(1, 1, 5);
    let err = train_lm_dp_native_run(&rc, None, &plan.stalls, &Pool::serial(), true)
        .expect_err("an over-budget straggler must fail the non-elastic run");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 1"), "diagnostic must name the dead rank: {msg}");
    assert!(msg.contains("--elastic"), "diagnostic must point at --elastic: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn elastic_reshard_is_deterministic_and_thread_invariant() {
    let dir = scratch("elastic");
    let steps = 6;
    let plan = FaultPlan::new(33).with_stall(1, 1, 5);
    let mut reference: Option<Vec<(String, HostTensor)>> = None;
    for (i, threads) in [1usize, 1, 2].iter().enumerate() {
        let pool =
            if *threads == 1 { Pool::serial() } else { Pool::new(*threads).with_min_chunk(1) };
        let mut rc = dp_rc(&dir, &format!("elastic_{i}"), steps, 2, 1);
        rc.elastic = true;
        let out = train_lm_dp_supervised(&rc, &plan, &pool, true).unwrap();
        // Rank 1 dies at step 1 (5 polls > budget 3); the fleet
        // reshards onto rank 0 at the next boundary.
        assert_eq!(
            out.reshards,
            vec![DpReshard { step: 2, dead_rank: 1, workers: 1 }],
            "run {i}"
        );
        assert_eq!(out.workers_final, 1, "run {i}");
        assert_eq!(out.stalls_recovered, 0, "run {i}");
        let fin = final_params(&rc.base);
        match &reference {
            None => reference = Some(fin),
            Some(want) => {
                assert_eq!(&fin, want, "run {i}: degraded trajectory is not reproducible")
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn within_budget_stall_leaves_the_trajectory_bitwise_unchanged() {
    let dir = scratch("stall_ok");
    let pool = Pool::serial();
    let base = dp_rc(&dir, "base", 4, 2, 1);
    train_lm_dp_native_run(&base, None, &[], &pool, true).unwrap();

    let rc = dp_rc(&dir, "stalled", 4, 2, 1);
    let plan = FaultPlan::new(33).with_stall(0, 1, 2).with_stall(1, 2, 3);
    let out = train_lm_dp_supervised(&rc, &plan, &pool, true).unwrap();
    assert_eq!(out.stalls_recovered, 2, "both stalls sit within the budget of 3");
    assert!(out.reshards.is_empty());
    assert_eq!(out.attempts, 1, "no kill ⇒ single launch");
    assert_eq!(
        final_params(&rc.base),
        final_params(&base.base),
        "absorbed stalls must not change the trajectory"
    );
    assert_eq!(replayed(&rc.base), replayed(&base.base));
    let _ = std::fs::remove_dir_all(&dir);
}
