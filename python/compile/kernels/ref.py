"""Pure-jnp reference oracle for PAMM and the baseline compressors.

This module is the *correctness ground truth* for the whole stack:

* the Pallas kernels in :mod:`compile.kernels.pamm` are asserted allclose
  against these functions in ``python/tests``;
* the native Rust implementation (``rust/src/pamm``) is asserted against
  HLO artifacts lowered from these functions;
* the custom-vjp layer (:mod:`compile.pamm_layer`) calls into this module
  (or its Pallas twins) for the compress/apply stages.

Everything here follows the paper's Algorithm 1 (Appendix A) exactly, with
one algebraic simplification used throughout the project: for the optimal
per-row scale ``alpha(i,j) = <A_i, C_j> / ||C_j||^2`` the reconstruction
error collapses to

    ||A_i - alpha * C_j||^2 = ||A_i||^2 * (1 - csim(A_i, C_j)^2)

so the neighborhood condition ``err <= eps * ||A_i||`` is equivalent to
``csim^2 >= 1 - eps^2`` — no reconstruction is ever materialized. This is
also the form the Pallas kernel uses (it avoids a (TB, n) temporary in
VMEM).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Epsilon used to guard divisions by (near-)zero row norms. Rows that are
# exactly zero get csim = 0 against every generator and are dropped by the
# neighborhood condition (alpha = 0), which matches the paper: a zero row
# contributes nothing to A^T B anyway.
_NORM_EPS = 1e-12

# Sentinel meaning "no neighborhood condition" (paper: eps = infinity).
EPS_INF = float("inf")


class PammCompressed(NamedTuple):
    """Compressed representation of a (b, n) activation matrix.

    Attributes:
      generators: ``C`` with shape (k, n) — sampled rows of ``A``.
      assign: ``f`` with shape (b,), int32 in [0, k) — generator index per row.
      alpha:  shape (b,) float32 — per-row scale; 0 marks a dropped row.
      beta:   scalar float32 — drop-correction factor ``b / (b - eta)``.
    """

    generators: jax.Array
    assign: jax.Array
    alpha: jax.Array
    beta: jax.Array

    @property
    def k(self) -> int:
        return self.generators.shape[0]


def sample_generator_indices(key: jax.Array, b: int, k: int) -> jax.Array:
    """Sample ``k`` distinct row indices from ``[0, b)`` (uniform, no repl.).

    Uses ``jax.random.permutation`` — O(b) but traced once; the paper's
    Appendix F measures index selection at <1% of forward time, and the
    same holds here (see EXPERIMENTS.md table7).
    """
    return jax.random.permutation(key, b)[:k].astype(jnp.int32)


def csim_matrix(a: jax.Array, c: jax.Array) -> jax.Array:
    """Row-wise cosine similarity matrix csim(A, C) ∈ R^{b×k}."""
    na = jnp.linalg.norm(a, axis=1, keepdims=True)  # (b, 1)
    nc = jnp.linalg.norm(c, axis=1, keepdims=True)  # (k, 1)
    dots = a @ c.T  # (b, k)
    return dots / jnp.maximum(na * nc.T, _NORM_EPS)


def compress(
    a: jax.Array,
    gen_idx: jax.Array,
    eps: float = EPS_INF,
) -> PammCompressed:
    """Stage 1 of PAMM (Algorithm 1, ``Compress``).

    Args:
      a: activation matrix ``A`` of shape (b, n).
      gen_idx: int32 (k,) indices into rows of ``a`` (the generating set).
        Sampling is done by the caller so the function stays functional and
        shape-static for AOT lowering.
      eps: neighborhood tolerance. ``EPS_INF`` disables the condition
        (the paper's best-performing setting); ``0`` keeps only rows that
        are exactly collinear with a generator (Uniform-CRS-like).

    Returns:
      A :class:`PammCompressed` tuple ``(C, f, alpha, beta)``.
    """
    b = a.shape[0]
    c = a[gen_idx]  # (k, n)
    cs = csim_matrix(a, c)  # (b, k)

    # Lemma 1: the best generator maximizes |csim|.
    abs_cs = jnp.abs(cs)
    f = jnp.argmax(abs_cs, axis=1).astype(jnp.int32)  # (b,)
    cs_best = jnp.take_along_axis(cs, f[:, None].astype(jnp.int32), axis=1)[:, 0]

    na = jnp.linalg.norm(a, axis=1)  # (b,)
    nc = jnp.linalg.norm(c, axis=1)  # (k,)
    alpha = cs_best * na / jnp.maximum(nc[f], _NORM_EPS)  # (b,)

    # Neighborhood condition via the csim^2 >= 1 - eps^2 equivalence.
    # eps >= 1 keeps every row (err <= ||A_i|| always holds at the optimum).
    if eps == EPS_INF or eps >= 1.0:
        keep = jnp.ones((b,), dtype=bool)
    else:
        # 1e-6 slack so exactly-collinear rows (csim = 1 up to float
        # rounding) survive eps = 0 — mirrored in the Pallas kernel and
        # the native Rust twin.
        keep = cs_best**2 >= 1.0 - float(eps) ** 2 - 1e-6
    # Rows with (near-)zero norm carry no signal; treat as dropped.
    keep = keep & (na > _NORM_EPS)
    alpha = jnp.where(keep, alpha, 0.0)

    # beta = b / (b - eta); if everything was dropped the estimate is the
    # zero matrix and beta's value is irrelevant — guard the division.
    kept = jnp.sum(keep.astype(jnp.float32))
    beta = jnp.where(kept > 0, b / jnp.maximum(kept, 1.0), 1.0)
    return PammCompressed(c, f, alpha.astype(a.dtype), beta.astype(a.dtype))


def apply_compressed(comp: PammCompressed, b_mat: jax.Array) -> jax.Array:
    """Stage 2 of PAMM (Algorithm 1, ``ApproxMM``): ``Õ ≈ βCᵀB̃``.

    ``B̃_j = Σ_{i: f(i)=j} α_i B_i`` is a segment-sum over the assignment,
    computed here with ``segment_sum`` (the Rust and Pallas twins use an
    index-accumulate and a one-hot matmul respectively — all three agree to
    float tolerance, asserted in tests).
    """
    k = comp.k
    weighted = comp.alpha[:, None] * b_mat  # (b, m)
    btilde = jax.ops.segment_sum(weighted, comp.assign, num_segments=k)  # (k, m)
    return comp.beta * (comp.generators.T @ btilde)  # (n, m)


def reconstruct(comp: PammCompressed) -> jax.Array:
    """Materialize Ã (Eq. 3) — test/analysis helper, never on hot paths."""
    return comp.alpha[:, None] * comp.generators[comp.assign]


def pamm_matmul(
    a: jax.Array,
    b_mat: jax.Array,
    gen_idx: jax.Array,
    eps: float = EPS_INF,
) -> jax.Array:
    """End-to-end PAMM approximation of ``O = AᵀB``."""
    return apply_compressed(compress(a, gen_idx, eps), b_mat)


def coverage(comp: PammCompressed) -> jax.Array:
    """Fraction of rows with a surviving representative (Fig. 7 metric)."""
    return jnp.mean((comp.alpha != 0).astype(jnp.float32))


def relative_l2_error(o_exact: jax.Array, o_approx: jax.Array) -> jax.Array:
    """``E(r, eps)`` from Appendix H (Fig. 6 metric)."""
    return jnp.linalg.norm(o_exact - o_approx) / jnp.maximum(
        jnp.linalg.norm(o_exact), _NORM_EPS
    )


# ---------------------------------------------------------------------------
# Baseline compressors (Section 4.6)
# ---------------------------------------------------------------------------


def uniform_crs_matmul(
    a: jax.Array, b_mat: jax.Array, gen_idx: jax.Array
) -> jax.Array:
    """Uniform Column-Row Sampling: keep only the sampled row pairs.

    Equivalent to PAMM with eps = 0 in the paper's framing: the only rows
    that survive an exact-collinearity test are the generators themselves
    (alpha = 1), and the β correction becomes b/k.
    """
    b = a.shape[0]
    k = gen_idx.shape[0]
    beta = b / k
    return beta * (a[gen_idx].T @ b_mat[gen_idx])


def compact_sketch(a: jax.Array, key: jax.Array, k: int) -> jax.Array:
    """CompAct's stored activation: the Gaussian sketch ``X̃ = XP``.

    ``P ∈ R^{n×k}`` has iid N(0, 1/k) entries so that ``E[PPᵀ] = I_n`` and
    the reconstruction ``X̂ = X̃Pᵀ`` (hence the gradient estimate) is
    unbiased. Only ``X̃`` (b×k) plus the PRNG key are stored; P is
    regenerated in the backward pass.
    """
    n = a.shape[1]
    p = jax.random.normal(key, (n, k), dtype=a.dtype) / jnp.sqrt(
        jnp.asarray(k, a.dtype)
    )
    return a @ p


def compact_matmul(
    sketch: jax.Array, b_mat: jax.Array, key: jax.Array, n: int
) -> jax.Array:
    """CompAct gradient estimate ``Õ = P(X̃ᵀ B)`` (regenerates P from key)."""
    k = sketch.shape[1]
    p = jax.random.normal(key, (n, k), dtype=sketch.dtype) / jnp.sqrt(
        jnp.asarray(k, sketch.dtype)
    )
    return p @ (sketch.T @ b_mat)


# ---------------------------------------------------------------------------
# Reference attention (the "exact softmax" oracle for the flash kernel)
# ---------------------------------------------------------------------------


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """Exact scaled-dot-product attention over (..., l, d) tensors."""
    d = q.shape[-1]
    scores = q @ jnp.swapaxes(k, -1, -2) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        l = q.shape[-2]
        mask = jnp.tril(jnp.ones((l, l), dtype=bool))
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    return probs @ v
