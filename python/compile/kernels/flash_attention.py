"""Blocked online-softmax attention as a Pallas kernel.

This is the project's stand-in for FlashAttention-v2 (Dao, 2023): the paper
never modifies attention internals — PAMM compresses the *inputs of the
Q/K/V projections*, upstream of the scaled-dot-product — and this kernel is
the composability witness: the e2e tests run PAMM projections feeding this
kernel and assert the combined computation matches the exact reference.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): FlashAttention's CUDA
formulation tiles over threadblocks with shared-memory staging. The TPU
reformulation tiles the *query* dimension on the grid (one (TQ, d) block in
VMEM per step), keeps K/V for the head resident in VMEM, and walks KV
blocks with a ``fori_loop`` carrying the online-softmax statistics
``(m, l, acc)`` — the HBM↔VMEM schedule is expressed by the BlockSpecs
instead of explicit cp.async staging.

Memory character matches FlashAttention: no (L, L) score matrix is ever
materialized; peak live state per grid step is TQ·d + L·d·2 + TQ·TK floats.
Runs under ``interpret=True`` (CPU portability — see kernels/pamm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool):
    """One (TQ, d) query block against all KV blocks of its head."""
    qblk = pl.program_id(1)

    # Blocks carry a leading head dim of size 1 (not squeezed by Pallas).
    q = q_ref[0]  # (TQ, d)
    k_full = k_ref[0]  # (L, d) — head-resident in VMEM
    v_full = v_ref[0]  # (L, d)
    tq, d = q.shape
    lk = k_full.shape[0]
    scale = 1.0 / (d**0.5)

    nblocks = lk // block_k
    if causal:
        # Blocks strictly above the diagonal contribute nothing; walking
        # them would only add masked-out work. The last relevant block is
        # the one containing this q block's final row.
        nblocks = jnp.minimum(
            nblocks, (qblk * tq + tq + block_k - 1) // block_k
        )

    q_ids = qblk * tq + jax.lax.iota(jnp.int32, tq)  # global query rows

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k_tile = jax.lax.dynamic_slice_in_dim(k_full, j * block_k, block_k)
        v_tile = jax.lax.dynamic_slice_in_dim(v_full, j * block_k, block_k)

        s = (
            jnp.dot(q, k_tile.T, preferred_element_type=jnp.float32) * scale
        )  # (TQ, TK)
        if causal:
            k_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_ids[:, None] >= k_ids[None, :]
            s = jnp.where(mask, s, _NEG_INF)

        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))  # (TQ,)
        p = jnp.exp(s - m_new[:, None])  # (TQ, TK)
        correction = jnp.exp(m_prev - m_new)  # (TQ,)
        l_new = l_prev * correction + jnp.sum(p, axis=1)
        acc = acc * correction[:, None] + jnp.dot(
            p, v_tile, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    m0 = jnp.full((tq,), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((tq,), dtype=jnp.float32)
    acc0 = jnp.zeros((tq, d), dtype=jnp.float32)
    _, l_fin, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, acc0))

    o_ref[0] = (acc / jnp.maximum(l_fin, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Flash attention over (h, l, d) per-head tensors.

    Grid = (heads, query blocks); K/V of the active head stay VMEM-resident
    across the inner KV walk. Matches ``ref.attention_ref`` to float32
    tolerance (tested, including the causal path).
    """
    h, l, d = q.shape
    bq = min(block_q, l)
    while l % bq:
        bq -= 1
    bk = min(block_k, l)
    while l % bk:
        bk -= 1
    grid = (h, l // bq)

    return pl.pallas_call(
        functools.partial(_flash_kernel, block_k=bk, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, l, d), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, l, d), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, l, d), q.dtype),
        interpret=True,
    )(q, k, v)
