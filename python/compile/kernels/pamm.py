"""Layer-1 Pallas kernels for PAMM.

Two kernels implement the paper's two stages (Algorithm 1), plus a tiled
matmul used for the final contraction:

* :func:`pamm_compress` — per-row generator assignment ``f`` and scale
  ``alpha``. The grid tiles the token dimension ``b``; each grid step holds
  one ``(TB, n)`` tile of ``A`` and the full ``(k, n)`` generator set in
  VMEM and computes the ``(TB, k)`` cosine-similarity block on the MXU.

* :func:`pamm_btilde` — the contraction ``B̃_j = Σ_{i: f(i)=j} α_i B_i``.
  The paper's CUDA implementation uses ``index_add`` (a scatter). Scatters
  serialize on a systolic array, so the TPU-shaped schedule here is a
  **one-hot matmul**: per tile, ``B̃ += (onehot(f) ⊙ α)ᵀ · B`` — a dense
  ``(k×TB)·(TB×m)`` MXU contraction accumulated across grid steps in the
  output ref. This is the DESIGN.md §Hardware-Adaptation point.

* :func:`matmul` — plain tiled matmul for ``Õ = β · CᵀB̃``.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to portable HLO that the
Rust runtime loads directly (the standalone-kernel artifacts in
``artifacts/`` are exactly these functions). Correctness is pinned to
``kernels/ref.py`` by ``python/tests/test_pamm_kernels.py``.

VMEM accounting (f32, per grid step), used by DESIGN/EXPERIMENTS §Perf:

    compress: TB·n (A tile) + k·n (C) + TB·k (csim) + O(TB + k)
    btilde:   TB·k (onehot)  + TB·m (B tile) + k·m (accumulator)
    matmul:   TN·TK + TK·TM + TN·TM

With the default TB=256 and the ``medium`` config (n=512, k ≤ 128,
m=512) the worst case is ~1.1 MiB — comfortably inside a 16 MiB VMEM with
room for double-buffering.
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NORM_EPS = 1e-12

# Default token-dimension tile. 256 rows keeps every operand tile MXU-shaped
# (multiples of 128 lanes) while bounding VMEM; see module docstring.
DEFAULT_BLOCK_B = 256


def _pick_block(total: int, preferred: int) -> int:
    """Largest divisor of ``total`` that is <= preferred (tiles must divide)."""
    tb = min(preferred, total)
    while total % tb != 0:
        tb -= 1
    return tb


# ---------------------------------------------------------------------------
# Stage 1: compress
# ---------------------------------------------------------------------------


def _compress_kernel(a_ref, c_ref, f_ref, alpha_ref, *, eps: float):
    """One (TB, n) tile: csim → argmax|csim| → alpha (+ eps mask).

    Uses the closed form err² = ‖A_i‖²(1 − csim²) so no reconstruction
    tile is materialized (see ref.py docstring).
    """
    a = a_ref[...]  # (TB, n)
    c = c_ref[...]  # (k, n)

    # MXU contraction + row norms (lane reductions).
    dots = jnp.dot(a, c.T, preferred_element_type=jnp.float32)  # (TB, k)
    na = jnp.sqrt(jnp.sum(a * a, axis=1))  # (TB,)
    nc = jnp.sqrt(jnp.sum(c * c, axis=1))  # (k,)
    denom = jnp.maximum(na[:, None] * nc[None, :], _NORM_EPS)
    cs = dots / denom  # (TB, k)

    # Lemma 1: best generator maximizes |csim|. k fits one lane row, so this
    # is a plain vector reduction (no tree reduction over cores needed).
    abs_cs = jnp.abs(cs)
    f = jnp.argmax(abs_cs, axis=1).astype(jnp.int32)  # (TB,)
    cs_best = jnp.max(abs_cs, axis=1) * jnp.sign(
        jnp.take_along_axis(cs, f[:, None], axis=1)[:, 0]
    )

    alpha = cs_best * na / jnp.maximum(nc[f], _NORM_EPS)

    if not (eps == float("inf") or eps >= 1.0):
        # 1e-6 slack: see ref.compress (keeps self-collinear rows at eps=0).
        keep = cs_best**2 >= 1.0 - float(eps) ** 2 - 1e-6
        alpha = jnp.where(keep, alpha, 0.0)
    alpha = jnp.where(na > _NORM_EPS, alpha, 0.0)

    f_ref[...] = f
    alpha_ref[...] = alpha.astype(alpha_ref.dtype)


def pamm_compress(
    a: jax.Array,
    c: jax.Array,
    eps: float = float("inf"),
    block_b: int = DEFAULT_BLOCK_B,
) -> Tuple[jax.Array, jax.Array]:
    """Pallas PAMM compress: returns ``(f, alpha)`` for generators ``c``.

    The generator *sampling* (and the β statistic, a cheap reduction over
    alpha) live outside the kernel; this keeps the kernel a pure dense
    stencil with static shapes.
    """
    b, n = a.shape
    k = c.shape[0]
    tb = _pick_block(b, block_b)
    grid = (b // tb,)

    f, alpha = pl.pallas_call(
        functools.partial(_compress_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, n), lambda i: (i, 0)),  # stream A tiles HBM→VMEM
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # C resident in VMEM
        ],
        out_specs=[
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), a.dtype),
        ],
        interpret=True,
    )(a, c)
    return f, alpha


def beta_from_alpha(alpha: jax.Array) -> jax.Array:
    """Drop-correction ``β = b/(b−η)`` from the alpha vector (Eq. 5)."""
    b = alpha.shape[0]
    kept = jnp.sum((alpha != 0).astype(jnp.float32))
    return jnp.where(kept > 0, b / jnp.maximum(kept, 1.0), 1.0).astype(alpha.dtype)


# ---------------------------------------------------------------------------
# Stage 2a: B̃ accumulation (the scatter, recast as one-hot matmul)
# ---------------------------------------------------------------------------


def _btilde_kernel(f_ref, alpha_ref, b_ref, out_ref, *, k: int):
    """Accumulate ``B̃ += (onehot(f)·α)ᵀ B`` for one b-tile.

    The output block index map is constant, so ``out_ref`` is the same
    (k, m) VMEM buffer across all grid steps — initialized at step 0 and
    accumulated afterwards (standard Pallas reduction idiom).
    """
    step = pl.program_id(0)

    f = f_ref[...]  # (TB,) int32
    alpha = alpha_ref[...]  # (TB,)
    b_tile = b_ref[...]  # (TB, m)

    onehot = (f[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(
        b_tile.dtype
    ) * alpha[:, None]  # (TB, k)
    partial = jnp.dot(onehot.T, b_tile, preferred_element_type=jnp.float32).astype(
        out_ref.dtype
    )  # (k, m)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(step != 0)
    def _accum():
        out_ref[...] += partial


def pamm_btilde(
    f: jax.Array,
    alpha: jax.Array,
    b_mat: jax.Array,
    k: int,
    block_b: int = DEFAULT_BLOCK_B,
) -> jax.Array:
    """Pallas ``B̃`` (k, m): segment-sum of ``α_i B_i`` over assignments."""
    b, m = b_mat.shape
    tb = _pick_block(b, block_b)
    grid = (b // tb,)

    return pl.pallas_call(
        functools.partial(_btilde_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb,), lambda i: (i,)),
            pl.BlockSpec((tb, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, m), b_mat.dtype),
        interpret=True,
    )(f, alpha, b_mat)


# ---------------------------------------------------------------------------
# Stage 2b: tiled matmul for Õ = β·CᵀB̃ (and general use)
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, y_ref, out_ref):
    """(TN, TK) @ (TK, TM) tile product accumulated over the K grid axis."""
    kstep = pl.program_id(2)
    part = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)

    @pl.when(kstep == 0)
    def _init():
        out_ref[...] = part

    @pl.when(kstep != 0)
    def _accum():
        out_ref[...] += part


def matmul(
    x: jax.Array,
    y: jax.Array,
    block_n: int = 128,
    block_m: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Tiled Pallas matmul ``x @ y`` with an MXU-shaped 3-D grid."""
    n, kdim = x.shape
    kdim2, m = y.shape
    assert kdim == kdim2, (x.shape, y.shape)
    tn = _pick_block(n, block_n)
    tm = _pick_block(m, block_m)
    tk = _pick_block(kdim, block_k)
    grid = (n // tn, m // tm, kdim // tk)

    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tm), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x, y)


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------


def pamm_matmul(
    a: jax.Array,
    b_mat: jax.Array,
    gen_idx: jax.Array,
    eps: float = float("inf"),
    block_b: int = DEFAULT_BLOCK_B,
) -> jax.Array:
    """End-to-end Pallas PAMM approximation of ``O = AᵀB``.

    Mirrors :func:`compile.kernels.ref.pamm_matmul` exactly (tested).
    """
    c = a[gen_idx]
    f, alpha = pamm_compress(a, c, eps=eps, block_b=block_b)
    beta = beta_from_alpha(alpha)
    btilde = pamm_btilde(f, alpha, b_mat, k=c.shape[0], block_b=block_b)
    return beta * matmul(c.T, btilde)
