"""Layer-2 training step: loss, AdamW, LR schedule — all AOT-lowerable.

``make_train_step`` produces the *single* jitted function the Rust
coordinator drives: ``(params…, m…, v…, step, tokens, seed) →
(loss, params…, m…, v…)``. Everything — forward, PAMM-compressed backward,
optimizer update, schedule — is one HLO module, so the request path never
leaves the PJRT executable.

Optimizer protocol (paper Appendix D): AdamW; base LR η tuned per size;
PAMM-compressed weights (wq/wk/wv) train with the reduced rate η̃ = α·η
(α = 0.25) for stability; linear warmup over the first 10% of steps, then
cosine decay to 10% of peak.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from compile import model as model_lib

Params = Dict[str, jax.Array]

# Weights whose gradient is PAMM-estimated → reduced LR (paper's α·η).
_COMPRESSED = ("wq", "wk", "wv")
# 1-D norm gains are excluded from weight decay (standard practice).
_NO_DECAY_SUFFIX = ("attn_norm", "ffn_norm", "final_norm")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters baked into the AOT artifact."""

    batch: int = 8
    seq: int = 128
    steps: int = 400
    lr: float = 3e-3
    pamm_lr_scale: float = 0.25  # the paper's α
    warmup_frac: float = 0.10
    final_lr_frac: float = 0.10
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def lr_at(tc: TrainConfig, step: jax.Array) -> jax.Array:
    """Warmup → cosine schedule (paper Appendix D), as traced arithmetic."""
    warm = jnp.maximum(1.0, tc.warmup_frac * tc.steps)
    total = float(tc.steps)
    s = step.astype(jnp.float32)
    warm_lr = tc.lr * (s + 1.0) / warm
    prog = jnp.clip((s - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
    cos = tc.final_lr_frac + (1.0 - tc.final_lr_frac) * 0.5 * (
        1.0 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(s < warm, warm_lr, tc.lr * cos)


def lm_loss(
    params: Params,
    tokens: jax.Array,
    cfg: model_lib.ModelConfig,
    var: model_lib.VariantConfig,
    seed: jax.Array,
    step: jax.Array,
) -> jax.Array:
    """Next-token cross-entropy (mean nats/token); ppl = exp(loss)."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = model_lib.lm_logits(params, inp, cfg, var, seed, step)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def classifier_loss(
    params: Params,
    tokens: jax.Array,
    labels: jax.Array,
    cfg: model_lib.ModelConfig,
    var: model_lib.VariantConfig,
    seed: jax.Array,
    step: jax.Array,
) -> jax.Array:
    logits = model_lib.classifier_logits(params, tokens, cfg, var, seed, step)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def _adamw_update(
    params: Params,
    grads: Params,
    m: Params,
    v: Params,
    step: jax.Array,
    tc: TrainConfig,
    compressed_active: bool,
) -> Tuple[Params, Params, Params]:
    """Manual AdamW with per-tensor LR scale and selective weight decay."""
    lr = lr_at(tc, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - tc.beta1**t
    bc2 = 1.0 - tc.beta2**t

    # Global-norm gradient clipping (stability at tiny batch sizes).
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads.values())
    )
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-12))

    new_p, new_m, new_v = {}, {}, {}
    for name, p in params.items():
        g = grads[name] * clip
        m_n = tc.beta1 * m[name] + (1.0 - tc.beta1) * g
        v_n = tc.beta2 * v[name] + (1.0 - tc.beta2) * g * g
        mh = m_n / bc1
        vh = v_n / bc2
        scale = tc.pamm_lr_scale if (compressed_active and name in _COMPRESSED) else 1.0
        upd = scale * lr * mh / (jnp.sqrt(vh) + tc.adam_eps)
        if tc.weight_decay > 0.0 and p.ndim >= 2 and not name.endswith(_NO_DECAY_SUFFIX):
            upd = upd + scale * lr * tc.weight_decay * p
        new_p[name] = p - upd
        new_m[name] = m_n
        new_v[name] = v_n
    return new_p, new_m, new_v


def make_train_step(
    cfg: model_lib.ModelConfig,
    var: model_lib.VariantConfig,
    tc: TrainConfig,
) -> Callable:
    """Decoder-LM training step (the artifact body for `train_step_*`)."""

    compressed_active = var.mode != "baseline"

    def train_step(params: Params, m: Params, v: Params, step, tokens, seed):
        loss, grads = jax.value_and_grad(lm_loss)(
            params, tokens, cfg, var, seed, step
        )
        new_p, new_m, new_v = _adamw_update(
            params, grads, m, v, step, tc, compressed_active
        )
        return loss, new_p, new_m, new_v

    return train_step


def make_grad_step(
    cfg: model_lib.ModelConfig,
    var: model_lib.VariantConfig,
    tc: TrainConfig,
) -> Callable:
    """Gradient-only step for the DDP/grad-accum coordinator path.

    Returns *raw* (unclipped) gradients: clipping by global norm must
    happen after the coordinator's all-reduce (correct DDP semantics),
    i.e. inside the apply artifact.
    """

    def grad_step(params: Params, step, tokens, seed):
        loss, grads = jax.value_and_grad(lm_loss)(
            params, tokens, cfg, var, seed, step
        )
        return loss, grads

    return grad_step


def make_apply_step(
    cfg: model_lib.ModelConfig,
    var: model_lib.VariantConfig,
    tc: TrainConfig,
) -> Callable:
    """Optimizer-apply step: consumes all-reduced gradients."""
    del cfg
    compressed_active = var.mode != "baseline"

    def apply_step(params: Params, m: Params, v: Params, grads: Params, step):
        return _adamw_update(params, grads, m, v, step, tc, compressed_active)

    return apply_step


def make_eval_step(cfg: model_lib.ModelConfig) -> Callable:
    """Loss-only forward (baseline variant — eval never compresses)."""

    var = model_lib.VariantConfig(mode="baseline")

    def eval_step(params: Params, tokens):
        return lm_loss(params, tokens, cfg, var, jnp.int32(0), jnp.int32(0))

    return eval_step


def make_classifier_train_step(
    cfg: model_lib.ModelConfig,
    var: model_lib.VariantConfig,
    tc: TrainConfig,
) -> Callable:
    """Finetune step for the GLUE/AID stand-ins (labels as extra input)."""

    compressed_active = var.mode != "baseline"

    def train_step(params: Params, m: Params, v: Params, step, tokens, labels, seed):
        loss, grads = jax.value_and_grad(classifier_loss)(
            params, tokens, labels, cfg, var, seed, step
        )
        new_p, new_m, new_v = _adamw_update(
            params, grads, m, v, step, tc, compressed_active
        )
        return loss, new_p, new_m, new_v

    return train_step


def make_classifier_eval_step(cfg: model_lib.ModelConfig) -> Callable:
    """Returns per-example predicted class ids (metrics live in Rust)."""

    var = model_lib.VariantConfig(mode="baseline")

    def eval_step(params: Params, tokens):
        logits = model_lib.classifier_logits(
            params, tokens, cfg, var, jnp.int32(0), jnp.int32(0)
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return eval_step


def init_opt_state(params: Params) -> Tuple[Params, Params]:
    zeros = {k: jnp.zeros_like(p) for k, p in params.items()}
    return zeros, {k: jnp.zeros_like(p) for k, p in params.items()}
