"""Layer-2 model zoo: LLaMA-family decoder LM + encoder classifier.

Architecture follows Touvron et al. (2023): RMSNorm (pre-norm), rotary
position embeddings, SwiGLU FFN, untied input/output embeddings, causal
multi-head attention. The Q/K/V projections route through
:func:`compile.pamm_layer.project`, which is where the paper's technique
plugs in; the output projection and the FFN are left untouched (paper
Appendix D.1 explains why the output projection is excluded).

Transformer blocks are evaluated with ``lax.scan`` over **stacked** layer
parameters — one (n_layers, …) array per weight kind. This keeps the
lowered HLO size and PJRT compile time independent of depth, and gives the
Rust runtime a fixed, small set of I/O tensors per config.

Config zoo: ``tiny``/``small``/``medium`` are CPU-trainable; the paper's
``llama60m``…``llama7b`` entries exist for the analytic memory/FLOP
accountant (rust/src/memory mirrors `param_count`/`qkv_activation_bytes`
below — cross-checked in tests) and for anyone re-running on an
accelerator.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from compile import pamm_layer
from compile.kernels import ref as ref_k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (hashable → usable as jit static)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int = 1024
    # Encoder-classifier extras (GLUE / AID stand-ins); None → decoder LM.
    n_classes: Optional[int] = None

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Exact trainable-parameter count (mirrored by rust/src/memory)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        head = d * v if self.n_classes is None else d * (self.n_classes or 0)
        return v * d + l * per_layer + d + head

    def qkv_activation_bytes(self, batch: int, seq: int, bytes_per: int = 4) -> int:
        """Bytes saved-for-backward by the Q/K/V projections, full baseline.

        One shared input tensor per attention block (Q, K and V read the
        same RMSNorm output; a framework stores that storage once), times
        n_layers. This is the quantity Fig. 3b / Table 5 track.
        """
        return self.n_layers * batch * seq * self.d_model * bytes_per

    def pamm_activation_bytes(
        self, batch: int, seq: int, r: float, bytes_per: int = 4
    ) -> int:
        """PAMM replacement cost, per projection (×3 per block): each of
        Q/K/V's custom backward saves its own C (k×n) + α (b) + f (b, i32)
        + β. Mirrored by rust/src/memory (see its module docs for why the
        baseline counts 1× but PAMM 3×)."""
        b = batch * seq
        k = max(1, math.ceil(r * b))
        per_proj = k * self.d_model * bytes_per + b * bytes_per + b * 4 + 4
        return self.n_layers * 3 * per_proj


@dataclasses.dataclass(frozen=True)
class VariantConfig:
    """Which compression runs in the Q/K/V backward (paper §4.6 axes)."""

    mode: str = "baseline"  # baseline | pamm | crs | compact
    r: float = 1.0 / 512.0
    eps: float = float("inf")
    use_pallas: bool = False

    def k_for(self, b_tokens: int) -> int:
        return max(1, math.ceil(self.r * b_tokens))


CONFIGS: Dict[str, ModelConfig] = {
    # CPU-trainable scales (runnable end to end through PJRT).
    "nano": ModelConfig("nano", vocab=256, d_model=64, n_layers=2, n_heads=2, d_ff=176),
    "tiny": ModelConfig("tiny", vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=344),
    "small": ModelConfig("small", vocab=1024, d_model=256, n_layers=6, n_heads=8, d_ff=688),
    "medium": ModelConfig("medium", vocab=2048, d_model=512, n_layers=8, n_heads=8, d_ff=1376),
    # Paper scales — analytic accounting + accelerator targets.
    "llama60m": ModelConfig("llama60m", vocab=32000, d_model=512, n_layers=8, n_heads=8, d_ff=1376),
    "llama350m": ModelConfig("llama350m", vocab=32000, d_model=1024, n_layers=24, n_heads=16, d_ff=2736),
    "llama1b": ModelConfig("llama1b", vocab=32000, d_model=2048, n_layers=24, n_heads=32, d_ff=5461),
    "llama7b": ModelConfig("llama7b", vocab=32000, d_model=4096, n_layers=32, n_heads=32, d_ff=11008),
}


def classifier_config(base: str, n_classes: int, name: Optional[str] = None) -> ModelConfig:
    """Derive an encoder-classifier config from a decoder entry."""
    cfg = CONFIGS[base]
    return dataclasses.replace(
        cfg, name=name or f"{base}-cls{n_classes}", n_classes=n_classes
    )


# ---------------------------------------------------------------------------
# Parameter spec / init
# ---------------------------------------------------------------------------

INIT_STD = 0.02


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], float]]:
    """Canonical ordered (name, shape, init_std) list.

    The order here *is* the AOT calling convention: aot.py flattens
    params/m/v in this order and records it in manifest.json; the Rust
    runtime initializes and feeds buffers in the same order.
    """
    d, f, l = cfg.d_model, cfg.d_ff, cfg.n_layers
    out_dim = cfg.n_classes if cfg.n_classes is not None else cfg.vocab
    resid_std = INIT_STD / math.sqrt(2 * l)  # GPT-2-style residual scaling
    return [
        ("embed", (cfg.vocab, d), INIT_STD),
        ("attn_norm", (l, d), -1.0),  # std<0 → init to ones
        ("wq", (l, d, d), INIT_STD),
        ("wk", (l, d, d), INIT_STD),
        ("wv", (l, d, d), INIT_STD),
        ("wo", (l, d, d), resid_std),
        ("ffn_norm", (l, d), -1.0),
        ("w_gate", (l, d, f), INIT_STD),
        ("w_up", (l, d, f), INIT_STD),
        ("w_down", (l, f, d), resid_std),
        ("final_norm", (d,), -1.0),
        ("head", (d, out_dim), INIT_STD),
    ]


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jax.Array]:
    """Gaussian init matching the spec (Rust mirrors this via manifest)."""
    params = {}
    for i, (name, shape, std) in enumerate(param_spec(cfg)):
        sub = jax.random.fold_in(key, i)
        if std < 0:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope_tables(seq: int, head_dim: int) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables, (seq, head_dim/2), base 10000 (LLaMA convention)."""
    half = head_dim // 2
    freq = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq, dtype=jnp.float32)
    ang = jnp.outer(t, freq)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs; x is (..., seq, head_dim)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v):
    """Exact causal attention (B, H, L, hd) — differentiable oracle.

    The Pallas flash kernel (kernels/flash_attention.py) implements the
    same computation for the inference/serving artifacts; training uses the
    exact form so autodiff derives the attention backward. PAMM is
    orthogonal to this choice by construction (it only wraps projections).
    """
    bsz, h, l, hd = q.shape
    qf = q.reshape(bsz * h, l, hd)
    kf = k.reshape(bsz * h, l, hd)
    vf = v.reshape(bsz * h, l, hd)
    of = ref_k.attention_ref(qf, kf, vf, causal=True)
    return of.reshape(bsz, h, l, hd)


def _block(x, layer_params, cfg: ModelConfig, var: VariantConfig, layer_key, causal=True):
    """One pre-norm transformer block; x is (B, L, d)."""
    (attn_norm, wq, wk, wv, wo, ffn_norm, w_gate, w_up, w_down) = layer_params
    bsz, l, d = x.shape
    b_tokens = bsz * l
    h, hd = cfg.n_heads, cfg.head_dim

    # --- attention sub-block ------------------------------------------------
    xn = rmsnorm(x, attn_norm)
    xf = xn.reshape(b_tokens, d)

    gen_key, compact_key = jax.random.split(layer_key)
    k_gen = var.k_for(b_tokens)
    gen_idx = ref_k.sample_generator_indices(gen_key, b_tokens, k_gen)

    q = pamm_layer.project(xf, wq, var.mode, gen_idx, var.eps, compact_key, k_gen, var.use_pallas)
    k = pamm_layer.project(xf, wk, var.mode, gen_idx, var.eps, compact_key, k_gen, var.use_pallas)
    v = pamm_layer.project(xf, wv, var.mode, gen_idx, var.eps, compact_key, k_gen, var.use_pallas)

    def heads(t):
        return t.reshape(bsz, l, h, hd).transpose(0, 2, 1, 3)  # (B, H, L, hd)

    q, k, v = heads(q), heads(k), heads(v)
    cos, sin = rope_tables(l, hd)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)

    if causal:
        attn = _attention(q, k, v)
    else:
        qf = q.reshape(bsz * h, l, hd)
        kf = k.reshape(bsz * h, l, hd)
        vf = v.reshape(bsz * h, l, hd)
        attn = ref_k.attention_ref(qf, kf, vf, causal=False).reshape(bsz, h, l, hd)
    attn = attn.transpose(0, 2, 1, 3).reshape(bsz, l, d)
    x = x + attn @ wo  # output projection stays full-memory (App. D.1)

    # --- SwiGLU FFN ----------------------------------------------------------
    xn = rmsnorm(x, ffn_norm)
    gated = jax.nn.silu(xn @ w_gate) * (xn @ w_up)
    return x + gated @ w_down


def forward(
    params: Dict[str, jax.Array],
    tokens: jax.Array,
    cfg: ModelConfig,
    var: VariantConfig,
    seed: jax.Array,
    step: jax.Array,
    causal: bool = True,
) -> jax.Array:
    """Token ids (B, L) → logits (B, L, vocab|n_classes-head input).

    ``seed``/``step`` are traced int32 scalars; each (step, layer) pair gets
    an independent generator sample, mirroring the paper's per-step
    resampling (Appendix F found generator reuse hurt quality).
    """
    x = params["embed"][tokens]  # (B, L, d)

    base_key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    step_key = jax.random.fold_in(base_key, step)

    stacked = tuple(
        params[n]
        for n in ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up", "w_down")
    )

    def scan_body(carry, inp):
        x, layer_ix = carry
        layer_params = inp
        layer_key = jax.random.fold_in(step_key, layer_ix)
        x = _block(x, layer_params, cfg, var, layer_key, causal=causal)
        return (x, layer_ix + 1), None

    (x, _), _ = jax.lax.scan(scan_body, (x, jnp.int32(0)), stacked)
    return rmsnorm(x, params["final_norm"])


def lm_logits(params, tokens, cfg, var, seed, step):
    h = forward(params, tokens, cfg, var, seed, step, causal=True)
    return h @ params["head"]  # (B, L, vocab)


def classifier_logits(params, tokens, cfg, var, seed, step):
    """Mean-pooled bidirectional encoder → class logits (GLUE/AID path)."""
    h = forward(params, tokens, cfg, var, seed, step, causal=False)
    pooled = jnp.mean(h, axis=1)  # (B, d)
    return pooled @ params["head"]  # (B, n_classes)
