"""Linear-projection layers with compressed saved activations.

Each variant is a ``jax.custom_vjp`` whose *forward output is exactly*
``x @ w`` — the compression only changes what is saved for backward and how
``∇W`` is estimated. ``∇X = ∇Z·Wᵀ`` is always exact (W is a parameter and
is stored regardless), matching the paper's key design point: the forward
pass and the gradients flowing to other layers are untouched.

Variants (Section 4.6 of the paper):

* :func:`pamm_linear`    — PAMM (this paper). Saves ``(C, f, α, β)``.
* :func:`crs_linear`     — Uniform-CRS (= PAMM with ε = 0). Saves sampled
  row pairs only.
* :func:`compact_linear` — CompAct (Shamshoum et al., 2025). Saves the
  Gaussian sketch ``X̃ = XP``.
* plain ``x @ w``        — the full-memory baseline (autodiff saves X).

Because the backward estimators live inside ``custom_vjp``, JAX never
differentiates *through* the Pallas kernels — so both the jnp reference and
the interpret-mode Pallas implementations are usable inside a jitted,
AOT-lowered training step (``use_pallas=True`` selects the kernels).

A note on memory under XLA AOT: unlike eager PyTorch, XLA decides buffer
lifetimes itself; the custom_vjp structure guarantees the *semantic*
residual set is {C, f, α, β} (O(kn + 2b)) instead of X (O(bn)), which is
what the Rust memory accountant (rust/src/memory) scores, and on a real
accelerator is what the compiler's liveness analysis materializes between
forward and backward of each layer.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import pamm as pamm_k
from compile.kernels import ref as ref_k


def _int_zero_tangent(x: jax.Array):
    """Cotangent for integer-valued primal inputs (jax wants float0)."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# PAMM
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def pamm_linear(
    x: jax.Array,
    w: jax.Array,
    gen_idx: jax.Array,
    eps: float = float("inf"),
    use_pallas: bool = False,
) -> jax.Array:
    """Linear layer ``x @ w`` whose backward uses PAMM for ``∇W``.

    Args:
      x: (b, n) flattened token activations (b = B·L).
      w: (n, m) projection weight.
      gen_idx: (k,) int32 sampled generator row indices (caller-sampled so
        the function stays deterministic & shape-static for AOT).
      eps: neighborhood tolerance (∞ disables, the paper's best setting).
      use_pallas: route compress/apply through the L1 Pallas kernels.
    """
    return x @ w


def _pamm_fwd(x, w, gen_idx, eps, use_pallas):
    z = x @ w
    if use_pallas:
        c = x[gen_idx]
        f, alpha = pamm_k.pamm_compress(x, c, eps=eps)
        beta = pamm_k.beta_from_alpha(alpha)
        comp = ref_k.PammCompressed(c, f, alpha, beta)
    else:
        comp = ref_k.compress(x, gen_idx, eps)
    # Residuals: the compressed representation instead of x — this is the
    # entire memory story of the paper (O(kn + 2b) vs O(bn)).
    return z, (comp, w, gen_idx)


def _pamm_bwd(eps, use_pallas, res, dz):
    comp, w, gen_idx = res
    if use_pallas:
        btilde = pamm_k.pamm_btilde(
            comp.assign, comp.alpha, dz, k=comp.generators.shape[0]
        )
        dw = comp.beta * pamm_k.matmul(comp.generators.T, btilde)
    else:
        dw = ref_k.apply_compressed(comp, dz)
    dx = dz @ w.T  # exact input gradient
    return dx, dw, _int_zero_tangent(gen_idx)


pamm_linear.defvjp(_pamm_fwd, _pamm_bwd)


# ---------------------------------------------------------------------------
# Uniform-CRS (PAMM with eps = 0)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def crs_linear(x: jax.Array, w: jax.Array, gen_idx: jax.Array) -> jax.Array:
    """Linear layer with Uniform Column-Row-Sampling backward."""
    return x @ w


def _crs_fwd(x, w, gen_idx):
    # Saves only the k sampled rows of x (and the index list).
    return x @ w, (x[gen_idx], w, gen_idx, x.shape[0])


def _crs_bwd(res, dz):
    c, w, gen_idx, b = res
    k = gen_idx.shape[0]
    dw = (b / k) * (c.T @ dz[gen_idx])
    dx = dz @ w.T
    return dx, dw, _int_zero_tangent(gen_idx)


crs_linear.defvjp(_crs_fwd, _crs_bwd)


# ---------------------------------------------------------------------------
# CompAct (Gaussian sketch baseline)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def compact_linear(
    x: jax.Array, w: jax.Array, key: jax.Array, k: int
) -> jax.Array:
    """Linear layer with CompAct's sketched backward (X̃ = XP stored)."""
    return x @ w


def _compact_fwd(x, w, key, k):
    sketch = ref_k.compact_sketch(x, key, k)
    return x @ w, (sketch, w, key, x.shape[1])


def _compact_bwd(k, res, dz):
    sketch, w, key, n = res
    dw = ref_k.compact_matmul(sketch, dz, key, n)
    dx = dz @ w.T
    return dx, dw, _int_zero_tangent(key)


compact_linear.defvjp(_compact_fwd, _compact_bwd)


# ---------------------------------------------------------------------------
# Variant dispatch + LoRA composition (Section 4.7)
# ---------------------------------------------------------------------------


def project(
    x: jax.Array,
    w: jax.Array,
    mode: str,
    gen_idx: jax.Array,
    eps: float,
    compact_key: jax.Array,
    compact_k: int,
    use_pallas: bool = False,
) -> jax.Array:
    """Uniform entry point used by the model for every Q/K/V projection.

    The three projections of one attention block share a single ``gen_idx``
    — the compression of their (shared) input is identical across the
    three custom-vjp instances, so XLA CSE folds it into one compress.
    """
    if mode == "baseline":
        return x @ w
    if mode == "pamm":
        return pamm_linear(x, w, gen_idx, eps, use_pallas)
    if mode == "crs":
        return crs_linear(x, w, gen_idx)
    if mode == "compact":
        return compact_linear(x, w, compact_key, compact_k)
    raise ValueError(f"unknown compression mode: {mode}")


def lora_pamm_linear(
    x: jax.Array,
    w0: jax.Array,
    lora_a: jax.Array,
    lora_b: jax.Array,
    gen_idx: jax.Array,
    eps: float = float("inf"),
    scaling: float = 1.0,
) -> jax.Array:
    """LoRA(x) = x·W₀ + s · (x·A)·B with PAMM on the A-adapter's input.

    W₀ is frozen (wrapped in stop_gradient); PAMM compresses x for ∇A —
    exactly the §4.7 configuration. Compressing for ∇B would save little
    (its input x·A is (b, rank), already tiny), matching the paper's note.
    """
    frozen = x @ jax.lax.stop_gradient(w0)
    adapted = pamm_linear(x, lora_a, gen_idx, eps, False) @ lora_b
    return frozen + scaling * adapted
