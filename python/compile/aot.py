"""AOT lowering: every runtime computation → HLO text + manifest.json.

This is the only place Python runs in the whole system, and it runs once
(`make artifacts`). Each artifact is a jitted JAX function lowered to
stablehlo, converted to an XlaComputation, and dumped as **HLO text** —
not a serialized ``HloModuleProto``: jax ≥ 0.5 emits 64-bit instruction
ids that the image's xla_extension 0.5.1 rejects, while the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

``manifest.json`` records, per artifact, the *exact* positional calling
convention (input/output names, shapes, dtypes) plus the parameter spec
(init std / shapes) so the Rust runtime can initialize, feed, and thread
buffers without ever importing Python.

Calling conventions
-------------------
train_step      : [param.*…, m.*…, v.*…, step, tokens, seed]
                  → [loss, param.*…, m.*…, v.*…]
cls_train_step  : same + labels before seed
eval_step       : [param.*…, tokens] → [loss]
cls_eval_step   : [param.*…, tokens] → [pred (B,) i32]
kernel artifacts: see ``emit_kernels``.

Everything is lowered with ``return_tuple=False`` so PJRT hands Rust one
buffer per output — the coordinator threads param/opt buffers straight
back into the next step without host round-trips.
"""

from __future__ import annotations

import argparse
import json
import math
import os
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import train as T
from compile.kernels import flash_attention as FA
from compile.kernels import pamm as PK
from compile.kernels import ref as RK

F32 = jnp.float32
I32 = jnp.int32


def spec(shape: Sequence[int], dtype=F32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _dt(x) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[str(x.dtype)]


def _io(name: str, x) -> Dict:
    return {"name": name, "shape": list(x.shape), "dtype": _dt(x)}


class Emitter:
    """Accumulates artifacts + manifest rows, writes them under ``outdir``."""

    def __init__(self, outdir: str):
        self.outdir = outdir
        self.rows: List[Dict] = []
        os.makedirs(outdir, exist_ok=True)

    def emit(
        self,
        name: str,
        fn: Callable,
        in_specs: List[Tuple[str, jax.ShapeDtypeStruct]],
        out_names: List[str],
        **meta,
    ) -> None:
        lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in in_specs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.outdir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *[s for _, s in in_specs])
        flat, _ = jax.tree_util.tree_flatten(outs)
        assert len(flat) == len(out_names), (name, len(flat), out_names)
        row = {
            "name": name,
            "file": fname,
            "inputs": [_io(n, s) for n, s in in_specs],
            "outputs": [_io(n, x) for n, x in zip(out_names, flat)],
        }
        row.update(meta)
        self.rows.append(row)
        print(f"  wrote {fname}  ({len(text) / 1024:.0f} KiB)")

    def finish(self) -> None:
        manifest = {
            "version": 1,
            "artifacts": self.rows,
            "configs": {
                name: {
                    "vocab": c.vocab,
                    "d_model": c.d_model,
                    "n_layers": c.n_layers,
                    "n_heads": c.n_heads,
                    "d_ff": c.d_ff,
                    "param_count": c.param_count(),
                }
                for name, c in M.CONFIGS.items()
            },
        }
        path = os.path.join(self.outdir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1)
        print(f"manifest: {path} ({len(self.rows)} artifacts)")


# ---------------------------------------------------------------------------
# Model artifacts
# ---------------------------------------------------------------------------


def _param_meta(cfg: M.ModelConfig) -> List[Dict]:
    return [
        {"name": n, "shape": list(s), "init_std": std}
        for n, s, std in M.param_spec(cfg)
    ]


def _variant_meta(var: M.VariantConfig) -> Dict:
    return {
        "mode": var.mode,
        "r": var.r,
        # JSON has no Infinity; -1 encodes "no neighborhood condition".
        "eps": -1.0 if math.isinf(var.eps) else var.eps,
        "use_pallas": var.use_pallas,
    }


def variant_tag(var: M.VariantConfig) -> str:
    if var.mode == "baseline":
        return "baseline"
    inv_r = int(round(1.0 / var.r))
    tag = f"{var.mode}{inv_r}"
    if var.use_pallas:
        tag += "pl"
    if not math.isinf(var.eps):
        tag += f"_eps{var.eps:g}".replace(".", "p")
    return tag


def emit_train_step(
    em: Emitter,
    cfg: M.ModelConfig,
    var: M.VariantConfig,
    tc: T.TrainConfig,
) -> None:
    pspec = M.param_spec(cfg)
    names = [n for n, _, _ in pspec]
    shapes = [s for _, s, _ in pspec]
    step_fn = T.make_train_step(cfg, var, tc)
    P = len(pspec)

    def flat_fn(*args):
        params = dict(zip(names, args[:P]))
        m = dict(zip(names, args[P : 2 * P]))
        v = dict(zip(names, args[2 * P : 3 * P]))
        step, tokens, seed = args[3 * P :]
        loss, np_, nm, nv = step_fn(params, m, v, step, tokens, seed)
        # Emit outputs in the same canonical order as inputs.
        return (
            loss,
            *[np_[n] for n in names],
            *[nm[n] for n in names],
            *[nv[n] for n in names],
        )

    in_specs = (
        [(f"param.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [(f"m.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [(f"v.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [
            ("step", spec((), I32)),
            ("tokens", spec((tc.batch, tc.seq + 1), I32)),
            ("seed", spec((), I32)),
        ]
    )
    out_names = (
        ["loss"]
        + [f"param.{n}" for n in names]
        + [f"m.{n}" for n in names]
        + [f"v.{n}" for n in names]
    )
    em.emit(
        f"train_{cfg.name}_{variant_tag(var)}_{tc.batch}x{tc.seq}",
        flat_fn,
        in_specs,
        out_names,
        kind="train_step",
        config=cfg.name,
        variant=_variant_meta(var),
        batch=tc.batch,
        seq=tc.seq,
        train={"lr": tc.lr, "steps": tc.steps, "pamm_lr_scale": tc.pamm_lr_scale},
        param_spec=_param_meta(cfg),
    )


def emit_grad_apply_pair(
    em: Emitter,
    cfg: M.ModelConfig,
    var: M.VariantConfig,
    tc: T.TrainConfig,
) -> None:
    """Grad-only + apply-only artifacts for the DDP/grad-accum coordinator.

    grads_* : [param.*, step, tokens, seed] → [loss, grad.*]
    apply_* : [param.*, m.*, v.*, grad.*, step] → [param.*, m.*, v.*]

    Clipping happens in apply (post-all-reduce — correct DDP semantics).
    """
    pspec = M.param_spec(cfg)
    names = [n for n, _, _ in pspec]
    shapes = [s for _, s, _ in pspec]
    P = len(pspec)
    grad_fn = T.make_grad_step(cfg, var, tc)
    apply_fn = T.make_apply_step(cfg, var, tc)

    def flat_grad(*args):
        params = dict(zip(names, args[:P]))
        step, tokens, seed = args[P:]
        loss, grads = grad_fn(params, step, tokens, seed)
        return (loss, *[grads[n] for n in names])

    em.emit(
        f"grads_{cfg.name}_{variant_tag(var)}_{tc.batch}x{tc.seq}",
        flat_grad,
        [(f"param.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [
            ("step", spec((), I32)),
            ("tokens", spec((tc.batch, tc.seq + 1), I32)),
            ("seed", spec((), I32)),
        ],
        ["loss"] + [f"grad.{n}" for n in names],
        kind="grad_step",
        config=cfg.name,
        variant=_variant_meta(var),
        batch=tc.batch,
        seq=tc.seq,
        train={"lr": tc.lr, "steps": tc.steps, "pamm_lr_scale": tc.pamm_lr_scale},
        param_spec=_param_meta(cfg),
    )

    def flat_apply(*args):
        params = dict(zip(names, args[:P]))
        m = dict(zip(names, args[P : 2 * P]))
        v = dict(zip(names, args[2 * P : 3 * P]))
        grads = dict(zip(names, args[3 * P : 4 * P]))
        step = args[4 * P]
        np_, nm, nv = apply_fn(params, m, v, grads, step)
        return (
            *[np_[n] for n in names],
            *[nm[n] for n in names],
            *[nv[n] for n in names],
        )

    em.emit(
        f"apply_{cfg.name}_{variant_tag(var)}_{tc.batch}x{tc.seq}",
        flat_apply,
        [(f"param.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [(f"m.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [(f"v.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [(f"grad.{n}", spec(s)) for n, s in zip(names, shapes)]
        + [("step", spec((), I32))],
        [f"param.{n}" for n in names]
        + [f"m.{n}" for n in names]
        + [f"v.{n}" for n in names],
        kind="apply_step",
        config=cfg.name,
        variant=_variant_meta(var),
        batch=tc.batch,
        seq=tc.seq,
        train={"lr": tc.lr, "steps": tc.steps, "pamm_lr_scale": tc.pamm_lr_scale},
        param_spec=_param_meta(cfg),
    )


def emit_eval_step(em: Emitter, cfg: M.ModelConfig, batch: int, seq: int) -> None:
    pspec = M.param_spec(cfg)
    names = [n for n, _, _ in pspec]
    eval_fn = T.make_eval_step(cfg)

    def flat_fn(*args):
        params = dict(zip(names, args[: len(names)]))
        return (eval_fn(params, args[len(names)]),)

    in_specs = [(f"param.{n}", spec(s)) for n, s, _ in pspec] + [
        ("tokens", spec((batch, seq + 1), I32))
    ]
    em.emit(
        f"eval_{cfg.name}_{batch}x{seq}",
        flat_fn,
        in_specs,
        ["loss"],
        kind="eval_step",
        config=cfg.name,
        batch=batch,
        seq=seq,
        param_spec=_param_meta(cfg),
    )


def emit_classifier(
    em: Emitter,
    cfg: M.ModelConfig,
    var: M.VariantConfig,
    tc: T.TrainConfig,
) -> None:
    pspec = M.param_spec(cfg)
    names = [n for n, _, _ in pspec]
    P = len(pspec)
    step_fn = T.make_classifier_train_step(cfg, var, tc)
    eval_fn = T.make_classifier_eval_step(cfg)

    def flat_train(*args):
        params = dict(zip(names, args[:P]))
        m = dict(zip(names, args[P : 2 * P]))
        v = dict(zip(names, args[2 * P : 3 * P]))
        step, tokens, labels, seed = args[3 * P :]
        loss, np_, nm, nv = step_fn(params, m, v, step, tokens, labels, seed)
        return (
            loss,
            *[np_[n] for n in names],
            *[nm[n] for n in names],
            *[nv[n] for n in names],
        )

    in_specs = (
        [(f"param.{n}", spec(s)) for n, s, _ in pspec]
        + [(f"m.{n}", spec(s)) for n, s, _ in pspec]
        + [(f"v.{n}", spec(s)) for n, s, _ in pspec]
        + [
            ("step", spec((), I32)),
            ("tokens", spec((tc.batch, tc.seq), I32)),
            ("labels", spec((tc.batch,), I32)),
            ("seed", spec((), I32)),
        ]
    )
    out_names = (
        ["loss"]
        + [f"param.{n}" for n in names]
        + [f"m.{n}" for n in names]
        + [f"v.{n}" for n in names]
    )
    em.emit(
        f"clstrain_{cfg.name}_{variant_tag(var)}_{tc.batch}x{tc.seq}",
        flat_train,
        in_specs,
        out_names,
        kind="cls_train_step",
        config=cfg.name,
        variant=_variant_meta(var),
        batch=tc.batch,
        seq=tc.seq,
        n_classes=cfg.n_classes,
        train={"lr": tc.lr, "steps": tc.steps, "pamm_lr_scale": tc.pamm_lr_scale},
        param_spec=_param_meta(cfg),
    )

    def flat_eval(*args):
        params = dict(zip(names, args[:P]))
        return (eval_fn(params, args[P]),)

    em.emit(
        f"clseval_{cfg.name}_{tc.batch}x{tc.seq}",
        flat_eval,
        [(f"param.{n}", spec(s)) for n, s, _ in pspec]
        + [("tokens", spec((tc.batch, tc.seq), I32))],
        ["pred"],
        kind="cls_eval_step",
        config=cfg.name,
        batch=tc.batch,
        seq=tc.seq,
        n_classes=cfg.n_classes,
        param_spec=_param_meta(cfg),
    )


# ---------------------------------------------------------------------------
# Standalone kernel artifacts (Rust cross-validates its native PAMM here)
# ---------------------------------------------------------------------------


def emit_kernels(em: Emitter, b: int = 1024, n: int = 128, m: int = 128, k: int = 8):
    """Pallas kernels as loadable executables + exact twins for deltas."""

    def compress_fn(a, c):
        f, alpha = PK.pamm_compress(a, c)
        return f, alpha, PK.beta_from_alpha(alpha)

    em.emit(
        f"k_compress_{b}x{n}_k{k}",
        compress_fn,
        [("a", spec((b, n))), ("c", spec((k, n)))],
        ["f", "alpha", "beta"],
        kind="kernel",
        kernel="pamm_compress",
        dims={"b": b, "n": n, "k": k},
    )

    def apply_fn(c, f, alpha, beta, bm):
        btilde = PK.pamm_btilde(f, alpha, bm, k=k)
        return (beta * PK.matmul(c.T, btilde),)

    em.emit(
        f"k_apply_{b}x{n}x{m}_k{k}",
        apply_fn,
        [
            ("c", spec((k, n))),
            ("f", spec((b,), I32)),
            ("alpha", spec((b,))),
            ("beta", spec(())),
            ("b_mat", spec((b, m))),
        ],
        ["o"],
        kind="kernel",
        kernel="pamm_apply",
        dims={"b": b, "n": n, "m": m, "k": k},
    )

    def pipeline_fn(a, bm, gen_idx):
        return (PK.pamm_matmul(a, bm, gen_idx),)

    em.emit(
        f"k_pamm_mm_{b}x{n}x{m}_k{k}",
        pipeline_fn,
        [("a", spec((b, n))), ("b_mat", spec((b, m))), ("gen_idx", spec((k,), I32))],
        ["o"],
        kind="kernel",
        kernel="pamm_matmul",
        dims={"b": b, "n": n, "m": m, "k": k},
    )

    def exact_fn(a, bm):
        return (a.T @ bm,)

    em.emit(
        f"k_exact_mm_{b}x{n}x{m}",
        exact_fn,
        [("a", spec((b, n))), ("b_mat", spec((b, m)))],
        ["o"],
        kind="kernel",
        kernel="exact_matmul",
        dims={"b": b, "n": n, "m": m},
    )

    h, l, d = 4, 128, 32

    def flash_fn(q, kk, v):
        return (FA.flash_attention(q, kk, v, causal=True),)

    em.emit(
        f"k_flash_{h}x{l}x{d}",
        flash_fn,
        [("q", spec((h, l, d))), ("k", spec((h, l, d))), ("v", spec((h, l, d)))],
        ["o"],
        kind="kernel",
        kernel="flash_attention",
        dims={"h": h, "l": l, "d": d},
    )

    def attn_ref_fn(q, kk, v):
        return (RK.attention_ref(q, kk, v, causal=True),)

    em.emit(
        f"k_attn_ref_{h}x{l}x{d}",
        attn_ref_fn,
        [("q", spec((h, l, d))), ("k", spec((h, l, d))), ("v", spec((h, l, d)))],
        ["o"],
        kind="kernel",
        kernel="attention_ref",
        dims={"h": h, "l": l, "d": d},
    )


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

INF = float("inf")


def preset_quick(em: Emitter) -> None:
    """Smallest useful set — CI smoke (nano config)."""
    cfg = M.CONFIGS["nano"]
    tc = T.TrainConfig(batch=4, seq=64, steps=200, lr=3e-3)
    for var in [
        M.VariantConfig("baseline"),
        M.VariantConfig("pamm", r=1 / 64),
        M.VariantConfig("pamm", r=1 / 64, use_pallas=True),
    ]:
        emit_train_step(em, cfg, var, tc)
    emit_eval_step(em, cfg, 4, 64)
    emit_grad_apply_pair(em, cfg, M.VariantConfig("pamm", r=1 / 64), tc)
    emit_kernels(em, b=512, n=64, m=64, k=8)


def preset_full(em: Emitter) -> None:
    """Everything the experiment harness (rust `pamm reproduce`) consumes."""
    # --- pretraining: fig3a / t5 / fig3b measured points -------------------
    size_tc = {
        "tiny": T.TrainConfig(batch=8, seq=128, steps=600, lr=3e-3),
        "small": T.TrainConfig(batch=8, seq=128, steps=500, lr=2e-3),
        "medium": T.TrainConfig(batch=4, seq=256, steps=400, lr=1e-3),
    }
    for cname, tc in size_tc.items():
        cfg = M.CONFIGS[cname]
        for var in [
            M.VariantConfig("baseline"),
            M.VariantConfig("pamm", r=1 / 128),
            M.VariantConfig("pamm", r=1 / 256),
            M.VariantConfig("pamm", r=1 / 512),
        ]:
            emit_train_step(em, cfg, var, tc)
        emit_eval_step(em, cfg, tc.batch, tc.seq)

    # Pallas-composed witness at small scale (kernels inside the step).
    emit_train_step(
        em,
        M.CONFIGS["tiny"],
        M.VariantConfig("pamm", r=1 / 128, use_pallas=True),
        size_tc["tiny"],
    )

    # DDP/grad-accum pair at tiny scale (table2a multi-worker rows).
    emit_grad_apply_pair(em, M.CONFIGS["tiny"], M.VariantConfig("pamm", r=1 / 512), size_tc["tiny"])
    emit_grad_apply_pair(em, M.CONFIGS["tiny"], M.VariantConfig("baseline"), size_tc["tiny"])

    # --- table3: batch/seq ablation on tiny, r = 1/512 ---------------------
    # Paper's 7 combos scaled /16 in both axes (same token-count ladder).
    for b_, l_ in [(8, 16), (8, 64), (16, 16), (16, 32), (32, 8), (32, 16), (32, 32)]:
        tc = T.TrainConfig(batch=b_, seq=l_, steps=300, lr=3e-3)
        for var in [M.VariantConfig("baseline"), M.VariantConfig("pamm", r=1 / 512)]:
            emit_train_step(em, M.CONFIGS["tiny"], var, tc)
        emit_eval_step(em, M.CONFIGS["tiny"], b_, l_)

    # --- fig4a: method comparison on tiny -----------------------------------
    tc = size_tc["tiny"]
    for r in [1 / 16, 1 / 64, 1 / 128, 1 / 256, 1 / 512]:
        for mode in ["pamm", "crs", "compact"]:
            emit_train_step(em, M.CONFIGS["tiny"], M.VariantConfig(mode, r=r), tc)

    # --- fig4b: eps ablation on tiny ----------------------------------------
    for r in [1 / 32, 1 / 128, 1 / 512]:
        for eps in [0.0, 0.5, INF]:
            if eps is INF:
                continue  # pamm r sweep above already covers eps=inf for 128/512
            emit_train_step(
                em, M.CONFIGS["tiny"], M.VariantConfig("pamm", r=r, eps=eps), tc
            )
    emit_train_step(em, M.CONFIGS["tiny"], M.VariantConfig("pamm", r=1 / 32), tc)

    # --- table1 / table4: finetune stand-ins --------------------------------
    glue_cfg = M.classifier_config("tiny", n_classes=4, name="glue")
    tc_ft = T.TrainConfig(batch=16, seq=64, steps=300, lr=1e-3, pamm_lr_scale=1.0)
    for var in [
        M.VariantConfig("baseline"),
        M.VariantConfig("pamm", r=1 / 128),
        M.VariantConfig("pamm", r=1 / 256),
    ]:
        emit_classifier(em, glue_cfg, var, tc_ft)

    aid_cfg = M.classifier_config("small", n_classes=30, name="aid")
    tc_aid = T.TrainConfig(batch=8, seq=64, steps=300, lr=1e-3, pamm_lr_scale=1.0)
    for var in [
        M.VariantConfig("baseline"),
        M.VariantConfig("pamm", r=1 / 128),
        M.VariantConfig("pamm", r=1 / 512),
    ]:
        emit_classifier(em, aid_cfg, var, tc_aid)

    # --- standalone kernels (t7/t8 + rust cross-validation) -----------------
    emit_kernels(em, b=1024, n=128, m=128, k=8)
    emit_kernels(em, b=2048, n=256, m=256, k=4)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="full", choices=["quick", "full"])
    args = ap.parse_args()
    em = Emitter(args.out)
    if args.preset == "quick":
        preset_quick(em)
    else:
        preset_quick(em)
        preset_full(em)
    em.finish()


if __name__ == "__main__":
    main()
