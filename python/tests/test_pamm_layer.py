"""Custom-vjp layer semantics: forward exactness, gradient estimators,
LoRA composition — the L2 contract the model relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import pamm_layer as PL
from compile.kernels import ref as RK


def _setup(b=256, n=32, m=24, k=8, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kw, kg = jax.random.split(key, 3)
    x = jax.random.normal(kx, (b, n), jnp.float32)
    w = 0.05 * jax.random.normal(kw, (n, m), jnp.float32)
    gi = RK.sample_generator_indices(kg, b, k)
    return x, w, gi


@pytest.mark.parametrize("use_pallas", [False, True])
def test_forward_is_exact(use_pallas):
    x, w, gi = _setup()
    z = PL.pamm_linear(x, w, gi, float("inf"), use_pallas)
    np.testing.assert_allclose(z, x @ w, rtol=1e-6, atol=1e-6)


def test_dx_is_exact_dw_is_pamm():
    """∇x must equal the exact linear-layer gradient; ∇w must equal the
    PAMM estimate computed directly from the compressed representation."""
    x, w, gi = _setup(seed=1)

    def loss(x, w):
        return jnp.sum(PL.pamm_linear(x, w, gi, float("inf"), False) ** 2)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    z = x @ w
    dz = 2.0 * z
    np.testing.assert_allclose(dx, dz @ w.T, rtol=1e-4, atol=1e-4)
    expect_dw = RK.pamm_matmul(x, dz, gi)
    np.testing.assert_allclose(dw, expect_dw, rtol=1e-4, atol=1e-4)


def test_pallas_and_ref_paths_agree_in_grad():
    x, w, gi = _setup(seed=2)

    def mk(use_pallas):
        def loss(w):
            return jnp.mean(PL.pamm_linear(x, w, gi, float("inf"), use_pallas) ** 2)

        return jax.grad(loss)(w)

    np.testing.assert_allclose(mk(True), mk(False), rtol=1e-4, atol=1e-5)


def test_crs_backward():
    x, w, gi = _setup(seed=3)

    def loss(w):
        return jnp.sum(PL.crs_linear(x, w, gi) ** 2)

    dw = jax.grad(loss)(w)
    dz = 2.0 * (x @ w)
    expect = RK.uniform_crs_matmul(x, dz, gi)
    np.testing.assert_allclose(dw, expect, rtol=1e-4, atol=1e-4)


def test_compact_backward():
    x, w, _ = _setup(seed=4)
    key = jax.random.PRNGKey(99)
    k = 8

    def loss(w):
        return jnp.sum(PL.compact_linear(x, w, key, k) ** 2)

    dw = jax.grad(loss)(w)
    dz = 2.0 * (x @ w)
    sketch = RK.compact_sketch(x, key, k)
    expect = RK.compact_matmul(sketch, dz, key, x.shape[1])
    np.testing.assert_allclose(dw, expect, rtol=1e-4, atol=1e-4)


def test_project_dispatch_baseline_matches_autodiff():
    """mode=baseline must be bit-identical to a plain linear layer."""
    x, w, gi = _setup(seed=5)
    key = jax.random.PRNGKey(0)

    def loss_plain(w):
        return jnp.sum((x @ w) ** 2)

    def loss_proj(w):
        z = PL.project(x, w, "baseline", gi, float("inf"), key, 8)
        return jnp.sum(z**2)

    np.testing.assert_allclose(
        jax.grad(loss_proj)(w), jax.grad(loss_plain)(w), rtol=1e-6, atol=1e-6
    )


def test_project_rejects_unknown_mode():
    x, w, gi = _setup()
    with pytest.raises(ValueError):
        PL.project(x, w, "bogus", gi, float("inf"), jax.random.PRNGKey(0), 8)


def test_lora_pamm_freezes_base_weight():
    x, w0, gi = _setup(seed=6)
    n, m = w0.shape
    rank = 4
    key = jax.random.PRNGKey(7)
    a = 0.1 * jax.random.normal(key, (n, rank))
    b = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (rank, m))

    def loss(w0, a, b):
        return jnp.sum(PL.lora_pamm_linear(x, w0, a, b, gi) ** 2)

    dw0, da, db = jax.grad(loss, argnums=(0, 1, 2))(w0, a, b)
    assert float(jnp.max(jnp.abs(dw0))) == 0.0  # frozen base
    assert float(jnp.max(jnp.abs(da))) > 0.0
    assert float(jnp.max(jnp.abs(db))) > 0.0


def test_lora_pamm_da_uses_pamm_estimate():
    x, w0, gi = _setup(seed=8)
    n, m = w0.shape
    rank = 4
    key = jax.random.PRNGKey(11)
    a = 0.1 * jax.random.normal(key, (n, rank))
    b = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (rank, m))

    def loss(a):
        return jnp.sum(PL.lora_pamm_linear(x, w0, a, b, gi, scaling=2.0) ** 2)

    da = jax.grad(loss)(a)
    # Manual: dz wrt adapter output = 2*out*scaling chain → d(adapted) path.
    out = x @ w0 + 2.0 * ((x @ a) @ b)
    d_adapted = 2.0 * out * 2.0  # dL/d(out) * scaling
    dz_a = d_adapted @ b.T  # gradient at the A-projection output
    expect = RK.pamm_matmul(x, dz_a, gi)
    np.testing.assert_allclose(da, expect, rtol=1e-3, atol=1e-4)
