"""L2 model + training step: shapes, variants, schedule, optimizer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T


CFG = M.CONFIGS["nano"]
TC = T.TrainConfig(batch=2, seq=32, steps=20)


def _tokens(seed=0, batch=2, seq=33):
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, seq), 0, CFG.vocab)


def _state():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    m, v = T.init_opt_state(params)
    return params, m, v


def test_param_spec_matches_init():
    params = M.init_params(CFG, jax.random.PRNGKey(1))
    spec = M.param_spec(CFG)
    assert set(params.keys()) == {n for n, _, _ in spec}
    for name, shape, std in spec:
        assert params[name].shape == shape
        if std < 0:
            np.testing.assert_array_equal(params[name], jnp.ones(shape))


def test_param_count_matches_spec():
    spec = M.param_spec(CFG)
    total = sum(int(np.prod(s)) for _, s, _ in spec)
    assert total == CFG.param_count()


def test_logits_shape_and_finite():
    params = M.init_params(CFG, jax.random.PRNGKey(2))
    tokens = _tokens(3, 2, 16)
    var = M.VariantConfig("baseline")
    logits = M.lm_logits(params, tokens, CFG, var, jnp.int32(0), jnp.int32(0))
    assert logits.shape == (2, 16, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    """Fresh init ⇒ loss ≈ ln(vocab) (sanity on the whole fwd path)."""
    params, m, v = _state()
    var = M.VariantConfig("baseline")
    loss = T.lm_loss(params, _tokens(5), CFG, var, jnp.int32(0), jnp.int32(0))
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5, float(loss)


@pytest.mark.parametrize("mode", ["baseline", "pamm", "crs", "compact"])
def test_train_step_decreases_loss(mode):
    params, m, v = _state()
    var = M.VariantConfig(mode, r=1 / 16)
    step = jax.jit(T.make_train_step(CFG, var, TC))
    tokens = _tokens(7)
    losses = []
    p, mm, vv = params, m, v
    for s in range(8):
        loss, p, mm, vv = step(p, mm, vv, jnp.int32(s), tokens, jnp.int32(3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses


def test_pamm_grads_differ_from_baseline_but_are_close():
    params, m, v = _state()
    tokens = _tokens(9)
    outs = {}
    for mode in ["baseline", "pamm"]:
        var = M.VariantConfig(mode, r=1 / 8)
        step = jax.jit(T.make_train_step(CFG, var, TC))
        _, p2, _, _ = step(params, m, v, jnp.int32(0), tokens, jnp.int32(5))
        outs[mode] = p2
    # wq is compressed → should differ; wo is untouched by PAMM fwd and
    # its gradient flows through exact paths → essentially identical.
    dq = float(jnp.max(jnp.abs(outs["pamm"]["wq"] - outs["baseline"]["wq"])))
    dwo = float(jnp.max(jnp.abs(outs["pamm"]["wo"] - outs["baseline"]["wo"])))
    assert dq > 1e-6
    assert dwo < 5e-3, dwo


def test_lr_schedule_shape():
    tc = T.TrainConfig(steps=100, lr=1e-2, warmup_frac=0.1, final_lr_frac=0.1)
    lrs = [float(T.lr_at(tc, jnp.int32(s))) for s in range(100)]
    peak = max(lrs)
    assert abs(peak - 1e-2) < 1e-5
    assert lrs.index(peak) <= 10  # peak right after warmup
    assert lrs[0] < lrs[5] <= peak  # warmup is increasing
    assert lrs[-1] < peak * 0.2  # decayed
    assert lrs[-1] >= peak * 0.09  # but floored at final_lr_frac


def test_grad_apply_pair_equals_fused_step():
    """grads→apply must produce the same update as the fused train step."""
    params, m, v = _state()
    var = M.VariantConfig("pamm", r=1 / 16)
    tokens = _tokens(11)
    fused = jax.jit(T.make_train_step(CFG, var, TC))
    loss_f, pf, mf, vf = fused(params, m, v, jnp.int32(0), tokens, jnp.int32(7))

    gstep = jax.jit(T.make_grad_step(CFG, var, TC))
    astep = jax.jit(T.make_apply_step(CFG, var, TC))
    loss_g, grads = gstep(params, jnp.int32(0), tokens, jnp.int32(7))
    pa, ma, va = astep(params, m, v, grads, jnp.int32(0))

    np.testing.assert_allclose(loss_f, loss_g, rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(pf[k], pa[k], rtol=1e-5, atol=1e-6, err_msg=k)
        np.testing.assert_allclose(mf[k], ma[k], rtol=1e-5, atol=1e-6, err_msg=k)


def test_classifier_shapes_and_learning():
    cfg = M.classifier_config("nano", n_classes=3)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    m, v = T.init_opt_state(params)
    var = M.VariantConfig("pamm", r=1 / 8)
    tc = T.TrainConfig(batch=8, seq=16, steps=30, lr=3e-3, pamm_lr_scale=1.0)
    step = jax.jit(T.make_classifier_train_step(cfg, var, tc))
    evalf = jax.jit(T.make_classifier_eval_step(cfg))

    key = jax.random.PRNGKey(4)
    # Learnable toy task: label = (first token) % 3.
    toks = jax.random.randint(key, (8, 16), 0, cfg.vocab)
    labels = toks[:, 0] % 3
    p, mm, vv = params, m, v
    first = None
    for s in range(30):
        loss, p, mm, vv = step(p, mm, vv, jnp.int32(s), toks, labels, jnp.int32(1))
        if first is None:
            first = float(loss)
    assert float(loss) < first
    preds = evalf(p, toks)
    assert preds.shape == (8,)
    assert preds.dtype == jnp.int32


def test_rope_preserves_norm():
    cos, sin = M.rope_tables(16, 8)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 8))
    rx = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        jnp.linalg.norm(rx, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )


def test_rmsnorm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 32)) * 100.0
    y = M.rmsnorm(x, jnp.ones(32))
    ms = jnp.mean(y * y, axis=-1)
    np.testing.assert_allclose(ms, jnp.ones(4), rtol=1e-3)


def test_memory_formulas_paper_scale():
    g = M.CONFIGS["llama60m"]
    assert g.qkv_activation_bytes(64, 256) == 256 * 1024 * 1024
    pamm = g.pamm_activation_bytes(64, 256, 1 / 512)
    assert pamm < g.qkv_activation_bytes(64, 256) * 0.03  # >97% savings
