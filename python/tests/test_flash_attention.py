"""Flash-attention Pallas kernel vs exact softmax attention."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import flash_attention as FA
from compile.kernels import ref as RK


def _qkv(h, l, d, seed=0, scale=1.0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    return tuple(scale * jax.random.normal(k, (h, l, d), jnp.float32) for k in ks)


SHAPES = [
    (1, 64, 16),
    (4, 128, 32),
    (2, 256, 64),
    (8, 64, 8),
]


@pytest.mark.parametrize("h,l,d", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_matches_exact(h, l, d, causal):
    q, k, v = _qkv(h, l, d, seed=h * 100 + l)
    out = FA.flash_attention(q, k, v, causal=causal)
    ref = RK.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32), (128, 128)])
def test_block_size_invariance(bq, bk):
    q, k, v = _qkv(2, 128, 16, seed=7)
    base = RK.attention_ref(q, k, v, causal=True)
    out = FA.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    np.testing.assert_allclose(out, base, rtol=2e-4, atol=2e-4)


def test_numerically_stable_large_logits():
    """Online softmax must survive logits that overflow naive exp."""
    q, k, v = _qkv(1, 64, 16, seed=11, scale=30.0)
    out = FA.flash_attention(q, k, v, causal=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = RK.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


def test_causal_first_row_is_v0():
    """Row 0 of causal attention can only attend to itself."""
    q, k, v = _qkv(1, 32, 8, seed=13)
    out = FA.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-5, atol=1e-5)


def test_causality_no_future_leak():
    """Perturbing future K/V must not change earlier outputs."""
    q, k, v = _qkv(1, 64, 16, seed=17)
    out1 = FA.flash_attention(q, k, v, causal=True)
    k2 = k.at[:, 48:, :].add(5.0)
    v2 = v.at[:, 48:, :].add(-3.0)
    out2 = FA.flash_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(out1[:, :48], out2[:, :48], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out1[:, 48:], out2[:, 48:])
