"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The hypothesis-style sweeps below are hand-rolled parameter grids (the
offline image has hypothesis, but deterministic grids keep CI time
bounded and failures reproducible without shrinking).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import pamm as PK
from compile.kernels import ref as RK

# Enable float64 comparisons where useful without global config churn.
jax.config.update("jax_enable_x64", False)


def _data(b, n, m, k, seed=0):
    key = jax.random.PRNGKey(seed)
    ka, kb, kg = jax.random.split(key, 3)
    a = jax.random.normal(ka, (b, n), jnp.float32)
    bm = jax.random.normal(kb, (b, m), jnp.float32)
    gi = RK.sample_generator_indices(kg, b, k)
    return a, bm, gi


SHAPES = [
    # (b, n, m, k) — swept across token counts, dims, generator counts
    (64, 8, 8, 1),
    (128, 16, 8, 2),
    (256, 32, 48, 4),
    (512, 64, 64, 8),
    (1024, 128, 96, 2),
    (1024, 48, 32, 64),
    (96, 24, 24, 96),  # k ≈ b edge
]


@pytest.mark.parametrize("b,n,m,k", SHAPES)
def test_pamm_matmul_matches_ref(b, n, m, k):
    a, bm, gi = _data(b, n, m, k, seed=b + n)
    o_ref = RK.pamm_matmul(a, bm, gi)
    o_pl = PK.pamm_matmul(a, bm, gi, block_b=min(128, b))
    np.testing.assert_allclose(o_pl, o_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("eps", [0.0, 0.2, 0.5, 0.9, 1.0, float("inf")])
def test_compress_eps_sweep(eps):
    b, n, k = 256, 32, 8
    a, _, gi = _data(b, n, 8, k, seed=17)
    c = a[gi]
    comp_ref = RK.compress(a, gi, eps)
    f_pl, al_pl = PK.pamm_compress(a, c, eps=eps, block_b=64)
    np.testing.assert_array_equal(f_pl, comp_ref.assign)
    np.testing.assert_allclose(al_pl, comp_ref.alpha, rtol=1e-5, atol=1e-6)
    beta_pl = PK.beta_from_alpha(al_pl)
    np.testing.assert_allclose(beta_pl, comp_ref.beta, rtol=1e-6)


@pytest.mark.parametrize("block_b", [32, 64, 128, 256])
def test_block_size_invariance(block_b):
    """Tiling must not change numerics (same result at any block size)."""
    a, bm, gi = _data(256, 32, 40, 4, seed=3)
    base = PK.pamm_matmul(a, bm, gi, block_b=256)
    tiled = PK.pamm_matmul(a, bm, gi, block_b=block_b)
    np.testing.assert_allclose(tiled, base, rtol=1e-5, atol=1e-5)


def test_btilde_is_segment_sum():
    b, m, k = 512, 24, 8
    key = jax.random.PRNGKey(5)
    f = jax.random.randint(key, (b,), 0, k, dtype=jnp.int32)
    alpha = jax.random.normal(jax.random.fold_in(key, 1), (b,))
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, m))
    bt = PK.pamm_btilde(f, alpha, bm, k=k, block_b=128)
    expect = jax.ops.segment_sum(alpha[:, None] * bm, f, num_segments=k)
    np.testing.assert_allclose(bt, expect, rtol=1e-5, atol=1e-5)


def test_matmul_kernel_various_tilings():
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (96, 64))
    y = jax.random.normal(jax.random.fold_in(key, 1), (64, 80))
    exact = x @ y
    for bn, bm_, bk in [(32, 40, 16), (96, 80, 64), (48, 16, 32)]:
        out = PK.matmul(x, y, block_n=bn, block_m=bm_, block_k=bk)
        np.testing.assert_allclose(out, exact, rtol=1e-4, atol=1e-4)


def test_lemma1_argmax_equals_argmin_distance():
    """Lemma 1: argmax |csim| picks the distance-minimizing generator."""
    a, _, gi = _data(128, 16, 8, 6, seed=21)
    c = a[gi]
    f, _ = PK.pamm_compress(a, c, block_b=64)
    # Exhaustive distances to the line spanned by each generator.
    al = (a @ c.T) / jnp.maximum(jnp.sum(c * c, axis=1)[None, :], 1e-12)
    recon = al[:, :, None] * c[None, :, :]  # (b, k, n)
    dists = jnp.linalg.norm(a[:, None, :] - recon, axis=-1)  # (b, k)
    best = jnp.argmin(dists, axis=1)
    np.testing.assert_array_equal(f, best)


def test_beta_unbiasedness_eps0():
    """E[Õ] ≈ O over generator resampling at ε = 0 (paper Eq. 5)."""
    b, n, m, k = 128, 12, 10, 16
    a, bm, _ = _data(b, n, m, k, seed=33)
    exact = a.T @ bm
    acc = jnp.zeros_like(exact)
    trials = 300
    for t in range(trials):
        gi = RK.sample_generator_indices(jax.random.PRNGKey(1000 + t), b, k)
        acc = acc + RK.pamm_matmul(a, bm, gi, eps=0.0)
    rel = jnp.linalg.norm(acc / trials - exact) / jnp.linalg.norm(exact)
    assert rel < 0.15, f"relative bias {rel}"


def test_full_generator_set_is_exact():
    b, n, m = 64, 16, 12
    a, bm, _ = _data(b, n, m, 1, seed=40)
    gi = jnp.arange(b, dtype=jnp.int32)
    o = RK.pamm_matmul(a, bm, gi)
    np.testing.assert_allclose(o, a.T @ bm, rtol=1e-3, atol=1e-3)


def test_coverage_and_error_shapes():
    """Fig 6/7 shapes: coverage ↑ in eps; error ↓ in eps."""
    a, bm, gi = _data(512, 32, 16, 8, seed=55)
    prev_cov = -1.0
    prev_err = None
    for eps in [0.0, 0.3, 0.7, float("inf")]:
        comp = RK.compress(a, gi, eps)
        cov = float(RK.coverage(comp))
        assert cov >= prev_cov - 1e-9
        prev_cov = cov
        err = float(
            RK.relative_l2_error(a.T @ bm, RK.apply_compressed(comp, bm))
        )
        if prev_err is not None and eps >= 0.3:
            assert err <= prev_err + 0.05
        prev_err = err
    assert prev_cov == 1.0  # eps = inf covers everything


def test_zero_rows_dropped():
    a, bm, gi = _data(64, 8, 6, 4, seed=60)
    a = a.at[10].set(0.0)
    comp = RK.compress(a, gi)
    assert comp.alpha[10] == 0.0
    assert float(comp.beta) == pytest.approx(64.0 / 63.0, rel=1e-5)
