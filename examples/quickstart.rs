//! Quickstart: PAMM as a library, no artifacts needed.
//!
//! Compresses a synthetic clustered activation matrix, runs the
//! approximate matmul, and prints the paper's three headline quantities:
//! memory ratio, relative error, and coverage.
//!
//! Run: `cargo run --release --example quickstart`

use pamm::pamm as pammc;
use pamm::pamm::Eps;
use pamm::rngx::Xoshiro256;
use pamm::tensor::Mat;

fn main() {
    // Clustered data, the regime PAMM exploits (tokens repeat patterns).
    let (b, n, m) = (4096, 256, 256);
    let nclust = 32;
    let mut rng = Xoshiro256::new(42);
    let centers = Mat::random_normal(nclust, n, 1.0, &mut rng);
    let mut a = Mat::zeros(b, n);
    for i in 0..b {
        let c = rng.next_below(nclust as u64) as usize;
        let scale = 0.5 + 1.5 * rng.next_f32();
        let row = a.row_mut(i);
        for j in 0..n {
            row[j] = scale * centers.get(c, j) + 0.05 * rng.next_normal() as f32;
        }
    }
    let grad = Mat::random_normal(b, m, 1.0, &mut rng);

    println!("PAMM quickstart — A is {b}×{n} ({} KiB)\n", b * n * 4 / 1024);
    println!("{:<8} {:>10} {:>12} {:>10} {:>10}", "1/r", "k", "stored", "rel_err", "coverage");
    let exact = pammc::exact_matmul(&a, &grad);
    for inv_r in [8usize, 32, 128, 512] {
        let k = (b / inv_r).max(1);
        let idx = pammc::sample_generators(&mut rng, b, k);
        let comp = pammc::compress(&a, &idx, Eps::Inf);
        let approx = pammc::apply(&comp, &grad);
        let err = approx.sub(&exact).frob_norm() / exact.frob_norm();
        println!(
            "{:<8} {:>10} {:>9} KiB {:>10.4} {:>10.2}",
            inv_r,
            k,
            comp.stored_bytes() / 1024,
            err,
            comp.coverage()
        );
    }
    println!(
        "\nAt r = 1/512 the stored state is ~{}× smaller than A — the \
         paper's 'fraction of their memory'.",
        b * n * 4 / pammc::compress(&a, &pammc::sample_generators(&mut rng, b, b / 512), Eps::Inf).stored_bytes()
    );
}
