//! ε-ablation demo on clustered activations (paper Fig 4b / Fig 6-7
//! mechanics, native path, no artifacts needed).
//!
//! Run: `cargo run --release --example ablation_epsilon`

use pamm::pamm::analysis;
use pamm::pamm::{compress, sample_generators, Eps};
use pamm::rngx::Xoshiro256;
use pamm::tensor::Mat;

fn clustered(b: usize, n: usize, nclust: usize, seed: u64) -> Mat {
    let mut rng = Xoshiro256::new(seed);
    let centers = Mat::random_normal(nclust, n, 1.0, &mut rng);
    let mut a = Mat::zeros(b, n);
    for i in 0..b {
        let c = rng.next_below(nclust as u64) as usize;
        let s = 0.5 + 1.5 * rng.next_f32();
        for j in 0..n {
            a.set(i, j, s * centers.get(c, j) + 0.08 * rng.next_normal() as f32);
        }
    }
    a
}

fn main() {
    let a = clustered(2048, 128, 24, 7);
    let mut rng = Xoshiro256::new(8);
    let bmat = Mat::random_normal(2048, 96, 1.0, &mut rng);

    println!("ε-ablation on clustered activations (b=2048, n=128):\n");
    println!("{:<8} {:<8} {:>10} {:>10} {:>8}", "1/r", "eps", "rel_err", "coverage", "beta");
    for inv_r in [16usize, 128, 512] {
        let k = (2048 / inv_r).max(1);
        for (etag, eps) in
            [("0", Eps::Val(0.0)), ("0.2", Eps::Val(0.2)), ("0.5", Eps::Val(0.5)), ("inf", Eps::Inf)]
        {
            let mut rng = Xoshiro256::new(100 + inv_r as u64);
            let idx = sample_generators(&mut rng, 2048, k);
            let comp = compress(&a, &idx, eps);
            let err = analysis::relative_error(
                &a,
                &bmat,
                1.0 / inv_r as f64,
                eps,
                &mut Xoshiro256::new(inv_r as u64),
            );
            println!(
                "{:<8} {:<8} {:>10.4} {:>10.3} {:>8.2}",
                inv_r,
                etag,
                err,
                comp.coverage(),
                comp.beta
            );
        }
        println!();
    }
    println!("Expected shape (paper Fig 4b / 6 / 7): error falls and coverage rises as ε→∞;\nε=∞ is uniformly best, and error grows only slowly as r shrinks.");
}
