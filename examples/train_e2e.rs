//! End-to-end headline run (DESIGN.md §12): train the largest
//! CPU-tractable LLaMA-style model through the full AOT→PJRT→coordinator
//! stack, baseline vs PAMM r = 1/512, logging both loss curves.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example train_e2e            # medium, 300 steps
//!   PAMM_E2E_QUICK=1 cargo run --release --example train_e2e   # tiny, 40
//!
//! The loss curves land in runs/e2e/*.csv; EXPERIMENTS.md records a run.

#[cfg(feature = "pjrt")]
use pamm::config::{RunConfig, Variant};
#[cfg(feature = "pjrt")]
use pamm::coordinator::train_run;
#[cfg(feature = "pjrt")]
use pamm::memory::{self, ModelGeometry};
#[cfg(feature = "pjrt")]
use pamm::runtime::Engine;

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "train_e2e drives the PJRT artifact runtime; rebuild with `--features pjrt`. \
         The artifact-free equivalent is `pamm train --native`."
    );
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let quick = std::env::var("PAMM_E2E_QUICK").is_ok();
    let engine = Engine::load("artifacts")?;

    let (model, batch, seq, steps) =
        if quick { ("tiny", 8, 128, 40) } else { ("medium", 4, 256, 300) };

    let mut results = Vec::new();
    for variant in [Variant::baseline(), Variant::pamm(512)] {
        let cfg = RunConfig {
            model: model.into(),
            variant: variant.clone(),
            batch,
            seq,
            steps,
            seed: 42,
            eval_every: (steps / 5).max(1),
            eval_batches: 6,
            run_dir: "runs/e2e".into(),
            ..Default::default()
        };
        println!("\n=== {} [{}] — {} steps ===", model, variant.tag(), steps);
        let out = train_run(&engine, &cfg, false)?;
        println!(
            "final: loss {:.4}, eval ppl {}, {} tok/s",
            out.final_loss,
            out.final_ppl.map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into()),
            out.tokens_per_sec.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
        );
        results.push((variant.tag(), out));
    }

    let g = ModelGeometry::by_name(model).unwrap();
    println!("\n=== summary ===");
    println!("model {model}: {} params", g.param_count());
    for (tag, out) in &results {
        println!(
            "  {tag:<12} final loss {:.4}  eval ppl {}",
            out.final_loss,
            out.final_ppl.map(|p| format!("{p:.2}")).unwrap_or_else(|| "-".into())
        );
    }
    println!(
        "QKV activation memory at this shape: baseline {}, PAMM {} (saved {:.2}%)",
        memory::fmt_bytes(memory::qkv_saved_bytes(&g, batch, seq, 4)),
        memory::fmt_bytes(memory::pamm_saved_bytes(&g, batch, seq, 1.0 / 512.0, 4)),
        memory::report(&g, batch, seq, Some(1.0 / 512.0)).savings_pct().unwrap()
    );
    println!("loss curves: runs/e2e/*.csv");
    Ok(())
}
