//! Finetuning example: SST2 stand-in task with PAMM r = 1/128 vs full
//! finetuning (paper Table 1's code path, one task).
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example finetune_glue

#[cfg(feature = "pjrt")]
use pamm::config::Variant;
#[cfg(feature = "pjrt")]
use pamm::coordinator::pipeline::LabeledPipeline;
#[cfg(feature = "pjrt")]
use pamm::coordinator::ClassifierSession;
#[cfg(feature = "pjrt")]
use pamm::data::glue::{self, TaskGenerator};
#[cfg(feature = "pjrt")]
use pamm::runtime::{Engine, HostTensor};

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "finetune_glue drives the PJRT artifact runtime; rebuild with `--features pjrt`."
    );
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    let engine = Engine::load("artifacts")?;
    let spec = glue::glue_suite().into_iter().find(|t| t.name == "SST2").unwrap();
    let steps = if std::env::var("PAMM_E2E_QUICK").is_ok() { 30 } else { 150 };

    for variant in [Variant::baseline(), Variant::pamm(128)] {
        let meta = engine
            .find(|a| {
                a.kind == "cls_train_step"
                    && a.config.as_deref() == Some("glue")
                    && a.variant_tag() == variant.tag()
            })
            .expect("glue artifacts (make artifacts)")
            .clone();
        let eval_name = meta
            .name
            .replace("clstrain", "clseval")
            .replace(&format!("_{}_", variant.tag()), "_");
        let mut session = ClassifierSession::new(&engine, &meta.name, &eval_name, 42)?;
        let vocab = engine.manifest.config("glue").unwrap().vocab;
        let pipe = LabeledPipeline::spawn(
            TaskGenerator::new(spec.clone(), vocab, 42),
            session.batch,
            session.seq,
            2,
        );
        println!("\n=== SST2 [{}] ===", variant.tag());
        for s in 0..steps {
            let b = pipe.next();
            let loss = session.step(
                &HostTensor::i32(vec![b.batch, b.seq], b.tokens.clone()),
                &HostTensor::i32(vec![b.batch], b.labels.clone()),
            )?;
            if s % (steps / 6).max(1) == 0 {
                println!("  step {s:>4}  loss {loss:.4}");
            }
        }
        let mut gen = TaskGenerator::new(spec.clone(), vocab, 42 ^ 0xEE);
        let (mut preds, mut golds) = (Vec::new(), Vec::new());
        for _ in 0..12 {
            let b = gen.batch(session.batch, session.seq);
            preds.extend(
                session.predict(&HostTensor::i32(vec![b.batch, b.seq], b.tokens.clone()))?,
            );
            golds.extend(b.labels);
        }
        println!("  accuracy: {:.2}%", glue::score(&spec, &preds, &golds));
    }
    Ok(())
}
