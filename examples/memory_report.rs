//! Memory accountant walk-through: the paper's Fig 3b / Table 5 memory
//! columns at every zoo scale, plus the k = ⌈r·b⌉ ladder.
//!
//! No artifacts required. Run:
//!   cargo run --release --example memory_report

use pamm::memory::{self, ModelGeometry};

fn main() {
    println!("QKV-activation memory, paper shapes (per-GPU 64×256 tokens):\n");
    println!(
        "{:<11} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "model", "params", "baseline", "r=1/128", "r=1/512", "saved"
    );
    for g in ModelGeometry::zoo() {
        let (b, l) = if g.name.starts_with("llama") { (64, 256) } else { (8, 128) };
        let base = memory::qkv_saved_bytes(&g, b, l, 4);
        let p128 = memory::pamm_saved_bytes(&g, b, l, 1.0 / 128.0, 4);
        let p512 = memory::pamm_saved_bytes(&g, b, l, 1.0 / 512.0, 4);
        println!(
            "{:<11} {:>12} {:>12} {:>12} {:>12} {:>7.2}%",
            g.name,
            g.param_count(),
            memory::fmt_bytes(base),
            memory::fmt_bytes(p128),
            memory::fmt_bytes(p512),
            100.0 * (1.0 - p512 as f64 / base as f64)
        );
    }

    println!("\nGenerator-count ladder at b = 16384 tokens (paper's per-GPU batch):");
    for inv_r in [64usize, 128, 256, 512] {
        let k = (16384f64 / inv_r as f64).ceil() as usize;
        println!("  r = 1/{inv_r:<4} → k = {k} generators");
    }
    println!("\nCompare against other compressors (llama60m, r = 1/128):");
    let g = ModelGeometry::by_name("llama60m").unwrap();
    println!("  PAMM    {}", memory::fmt_bytes(memory::pamm_saved_bytes(&g, 64, 256, 1.0 / 128.0, 4)));
    println!("  CRS     {}", memory::fmt_bytes(memory::crs_saved_bytes(&g, 64, 256, 1.0 / 128.0)));
    println!("  CompAct {}", memory::fmt_bytes(memory::compact_saved_bytes(&g, 64, 256, 1.0 / 128.0)));
}
